"""Production-traffic scenario harness: churn, storms, crashes.

Figures 6-8 each reproduce one clean event -- a single scale-out, a
single hot-key storm, a single failure.  Production traffic composes
them: the autoscaler churns membership while a flash crowd concentrates
load and a KN dies mid-batch.  This harness runs those compositions
against the real data structures with the fault plane armed, and turns
the paper's robustness claims into SLO rows:

  churn     an oscillating offered load drives the PolicyEngine through
            continuous join/leave churn; the ring must never empty,
            every reconfiguration stays bounded, integrity holds at the
            end of the run.
  storm     a flash crowd redirects a fraction of traffic onto a
            handful of hot keys mid-run, stressing selective
            replication and the Eq. 1 screen; throughput must not
            collapse onto the hot keys' owner.
  crash     a KN fail-stops at a named (seeded) crash point under
            write-heavy load -- armed mid-batch when the point fires
            inside the observed step, forced otherwise -- and the
            recovery plane (DPMPool.recover_kn) repairs the pool;
            downtime is measured as an SLO: recovery window,
            minimum-throughput fraction during recovery, and
            zero-throughput epochs.
  composed  all of the above at once: churn plus a storm window plus a
            crash at the storm's peak.

Two fencing scenarios (ownership variants only) exercise the epoch
fence under imperfect failure detection:

  partition a KN loses its DPM link mid-run (its requests block), a
            second KN goes gray (fail-slow); the partition heals on
            schedule and delivery must recover -- no false failure.
  zombie    the false-positive story: a partitioned-but-alive KN is
            declared dead, ownership hands off, the zombie heals and
            flushes its staged oplog with its stale fence token.  Every
            flush must no-op (``FencedWrite``), the acked history must
            stay linearizable, and detection latency is gated.

``violations`` in a result row collects integrity failures
(DPMPool.verify_integrity), an emptied ring, or a dead cluster at the
end of a run -- a healthy variant reports zero.  Network faults
(dropped flush RTs, delayed heartbeats) ride along on every scenario
via the seeded FaultPlane, so the SLOs are measured under realistic
noise, not lab silence.

Run one scenario:  ``run_scenario("composed", "dinomo", seed=0)``
Emit the bench:    ``python -m benchmarks.bench_scenarios [--smoke]``
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field

import numpy as np

from .cluster import DinomoCluster, VARIANTS
from .dpm_pool import FencedWrite
from .faults import (ALL_POINTS, ARMABLE_POINTS, CRASH_POINTS,
                     FaultPlane, KNCrash)
from .linearizability import Op, check_history
from .mnode import PolicyConfig
from .netmodel import (ArrivalProcess, DEFAULT_MODEL, NetModel,
                       PhasedArrival)
from .requestplane import RequestPlaneConfig
from .simulate import TimedSimulation
from ..data.ycsb import MIXES, Workload

SCENARIOS = ("churn", "storm", "crash", "composed")
# fencing scenarios: meaningful only for variants with logical
# ownership (a shared-everything plane has no epochs to fence)
FENCE_SCENARIOS = ("partition", "zombie")
BENCH_VARIANTS = ("dinomo", "dinomo-n", "clover")


@dataclass
class ScenarioConfig:
    """Knobs for one scenario run; ``smoke()`` is the CI profile."""
    num_kns: int = 4
    num_keys: int = 20_000
    cache_bytes: int = 1 << 19
    value_bytes: int = 1024
    num_buckets: int = 1 << 14
    segment_capacity: int = 256
    sample_ops: int = 2000
    dt: float = 1.0
    duration_s: float = 120.0
    dataset_bytes: float = 32e9          # represented scale (paper Sec. 5)
    # load shape: base_load sits inside the policy's stable band for
    # the starting cluster (no spurious scaling in steady scenarios);
    # churn oscillates between churn_low (remove band) and peak_load
    # (add band); storms bump to storm_load inside the window
    base_load: float = 8e5
    churn_low: float = 2e5
    peak_load: float = 8e6
    storm_load: float = 5e6
    churn_period_s: float = 40.0
    # storm window
    storm_start_s: float = 40.0
    storm_end_s: float = 80.0
    storm_frac: float = 0.7
    storm_hot: int = 4
    # crash
    crash_at_s: float = 60.0
    # partition / zombie (fencing scenarios)
    partition_at_s: float = 30.0
    partition_heal_s: float = 20.0       # outage length before heal
    gray_slow_factor: float = 4.0        # fail-slow RT multiplier
    zombie_staged_ops: int = 24          # oplog the zombie flushes at heal
    # background network faults
    drop_flush_rt_rate: float = 0.01
    heartbeat_delay_s: float = 0.01
    heartbeat_jitter_s: float = 0.01
    # policy
    epoch_s: float = 5.0
    grace_period_s: float = 10.0
    max_kns: int = 8

    @classmethod
    def smoke(cls) -> "ScenarioConfig":
        return cls(num_keys=3000, num_buckets=1 << 13, sample_ops=400,
                   duration_s=40.0, churn_period_s=16.0,
                   storm_start_s=10.0, storm_end_s=28.0,
                   crash_at_s=18.0, partition_at_s=10.0,
                   partition_heal_s=12.0, zombie_staged_ops=12,
                   epoch_s=4.0, grace_period_s=8.0)


@dataclass
class ScenarioResult:
    scenario: str
    variant: str
    seed: int
    crash_point: str | None
    duration_s: float
    recovery_window_s: float | None
    min_tput_during_frac: float | None
    zero_tput_epochs: int
    membership_changes: int
    replication_actions: int
    flush_rts_dropped: int
    recovery: dict | None
    violations: list[str] = field(default_factory=list)
    events: list[str] = field(default_factory=list)
    # scenario-specific observables (fence scenarios: zombie attempt /
    # fenced counts, detection latency, delivery through a partition)
    extra: dict = field(default_factory=dict)

    def row(self) -> dict:
        return {
            "scenario": self.scenario, "variant": self.variant,
            "seed": self.seed, "crash_point": self.crash_point,
            "duration_s": self.duration_s,
            "recovery_window_s": self.recovery_window_s,
            "min_tput_during_frac": self.min_tput_during_frac,
            "zero_tput_epochs": self.zero_tput_epochs,
            "membership_changes": self.membership_changes,
            "replication_actions": self.replication_actions,
            "flush_rts_dropped": self.flush_rts_dropped,
            "recovery": self.recovery,
            "violations": self.violations,
            "extra": self.extra,
        }


class StormWorkload:
    """Flash-crowd wrapper over a base Workload: during [t0, t1) a
    fraction ``frac`` of the sampled ops redirect (uniformly) onto a
    small hot set -- the sudden skew spike selective replication and
    the Eq. 1 screen exist to absorb."""

    def __init__(self, base: Workload, hot: list[int], frac: float,
                 t0: float, t1: float):
        self.base = base
        self.hot = np.asarray(hot, dtype=np.int64)
        self.frac = frac
        self.t0, self.t1 = t0, t1

    def timed_batched(self, t: float, rng, n: int):
        kinds, keys = self.base.ops_arrays(n)
        if self.t0 <= t < self.t1 and self.hot.size:
            m = rng.random(n) < self.frac
            hits = int(m.sum())
            if hits:
                keys = keys.copy()
                keys[m] = self.hot[rng.integers(0, self.hot.size, hits)]
        return kinds, keys


def _offered_fn(scenario: str, cfg: ScenarioConfig):
    if scenario in ("churn", "composed"):
        # full sine sweep: troughs dip to churn_low (the policy's remove
        # band), peaks reach peak_load (the add band) -- continuous
        # join/leave churn by construction
        def offered(t: float) -> float:
            phase = math.sin(2.0 * math.pi * t / cfg.churn_period_s)
            lo, hi = cfg.churn_low, cfg.peak_load
            return lo + (hi - lo) * max(phase, 0.0)
        return offered
    if scenario == "storm":
        # the flash crowd brings extra load with it -- enough to
        # overload the hot keys' owner unless replication spreads it
        return lambda t: (cfg.storm_load
                          if cfg.storm_start_s <= t < cfg.storm_end_s
                          else cfg.base_load)
    # crashes run against a steady in-band load so the SLO fractions
    # measure the event, not the load shape
    return lambda t: cfg.base_load


def _pick_victim(c: DinomoCluster, skip=()) -> str | None:
    """The alive KN with the most unmerged log state -- the most
    interesting crash victim -- ties broken by name for determinism."""
    best, best_pending = None, -1
    for name in sorted(c.kns):
        if not c.kns[name].alive or name in skip:
            continue
        pending = sum(len(s.entries) - s.merged_upto
                      for s in c.pool.segments.get(name, ()))
        if pending > best_pending:
            best, best_pending = name, pending
    return best


def _crash_and_recover(sim: TimedSimulation, faults: FaultPlane,
                       point: str, offered, result: ScenarioResult,
                       skip=()):
    """Crash a KN at ``point`` mid-run: arm the crash point so it fires
    inside the next step's batched write/merge paths when it can (the
    mid-batch flavor), force the equivalent state corruption when the
    step completes without reaching it (e.g. Clover's inline-merge plane
    or a point the victim never hits), then fail the KN through the
    timed reconfiguration path and verify pool integrity."""
    c = sim.c
    victim = _pick_victim(c, skip=skip)
    if victim is None or len(sim._alive_kns()) <= 1 + len(skip):
        result.events.append("crash skipped: no eligible victim")
        return
    armed = point in ARMABLE_POINTS and c.variant.name != "clover"
    if armed:
        faults.arm_crash(point, kn=victim,
                         after=int(faults.rng.integers(0, 64)))
    crashed = False
    try:
        sim.step(offered(sim.now), [f"crash {victim}@{point}"])
        sim.now += sim.dt
    except KNCrash as e:
        crashed = True
        victim = e.kn
        result.events.append(f"t={sim.now:.1f} {victim} crashed "
                             f"mid-batch at {point}")
    faults.disarm()
    if not crashed:
        rec = faults.force_crash(c.pool, victim, point)
        result.events.append(f"t={sim.now:.1f} forced {point} on "
                             f"{victim}: {rec['effect']}")
    window = sim.inject_failure(victim)
    result.recovery_window_s = window
    result.recovery = (c.reconfig_log[-1].get("recovery")
                       if c.reconfig_log else None)
    result.violations.extend(
        f"post-recovery: {v}" for v in c.pool.verify_integrity())


def _keys_owned_by(c: DinomoCluster, kn: str, start: int,
                   count: int) -> list[int]:
    """``count`` sentinel keys (outside the workload key range) whose
    ring owner is ``kn`` -- a key timeline the background traffic never
    touches, so linearizability can be checked exactly."""
    out: list[int] = []
    k = start
    while len(out) < count and k < start + 500_000:
        if c.ownership.primary(k) == kn:
            out.append(k)
        k += 1
    return out


def _run_partition(sim: TimedSimulation, faults: FaultPlane,
                   cfg: ScenarioConfig, offered,
                   result: ScenarioResult,
                   point: str | None = None) -> None:
    """A KN loses its DPM link for ``partition_heal_s`` seconds while a
    second KN goes gray (fail-slow).  No failure is injected for the
    partitioned KN: the partition must degrade delivery while open and
    delivery must recover once it heals.  With ``point`` set (the chaos
    matrix), a *different* KN crashes at that armed crash point while
    the partition is still open -- recovery must stay clean with the
    partition degrading the cluster underneath it."""
    c = sim.c
    sim.run(cfg.partition_at_s, offered)
    t0 = sim.now
    victim = _pick_victim(c)
    if victim is None:
        result.events.append("partition skipped: no eligible victim")
        sim.run(cfg.duration_s, offered)
        return
    t1 = t0 + cfg.partition_heal_s
    faults.partition(victim, "kn-dpm", start_s=t0, end_s=t1)
    gray = next((n for n in sorted(c.kns)
                 if n != victim and c.kns[n].alive), None)
    if gray is not None:
        faults.fail_slow(gray, cfg.gray_slow_factor, start_s=t0, end_s=t1)
    sim.log_event("partition", node=victim, net="kn-dpm",
                  heal_s=round(t1, 6))
    if point is not None:
        sim.run(min(t0 + cfg.partition_heal_s / 2, cfg.duration_s),
                offered)
        _crash_and_recover(sim, faults, point, offered, result,
                           skip=(victim,))
    sim.run(cfg.duration_s, offered)
    healed = faults.heal_partitions(victim, t=sim.now)
    sim.log_event("partition_healed", node=victim, open_windows=healed)
    during = [p.throughput / p.offered for p in sim.trace
              if t0 <= p.t < t1 and p.offered > 0]
    after = [p.throughput / p.offered for p in sim.trace
             if p.t >= t1 and p.offered > 0]
    result.extra = {
        "partitioned_kn": victim, "gray_kn": gray,
        "min_delivery_during": min(during) if during else None,
        "mean_delivery_after": (sum(after) / len(after)) if after else None,
    }
    if victim in c.kns and not c.kns[victim].alive:
        result.violations.append(
            "partition: healed KN was permanently failed (false positive)")


def _run_zombie(sim: TimedSimulation, faults: FaultPlane,
                cfg: ScenarioConfig, offered,
                result: ScenarioResult) -> None:
    """The false-positive detection story (paper Sec. 3.5/3.6 made safe
    under imperfect detection):

      1. a KN is partitioned from the M-node (alive, still serving);
      2. missed heartbeats declare it dead -> ownership hands off and
         the fence generation bumps;
      3. the partition heals and the zombie flushes its staged oplog
         (writes it accepted while partitioned) with its stale token.

    Every flush -- log writes, a batched fill, an indirection CAS, even
    a replayed recovery -- must come back ``FencedWrite`` without
    touching pool state, and the acked history (pre-handoff writes +
    new-owner writes + final reads) must stay linearizable with the
    fenced ops dropped."""
    c = sim.c
    pool = c.pool
    sim.run(cfg.partition_at_s, offered)
    victim = _pick_victim(c)
    if victim is None or len(sim._alive_kns()) <= 1:
        result.events.append("zombie skipped: no eligible victim")
        sim.run(cfg.duration_s, offered)
        return
    stale_token = c.kns[victim].fence_token
    zkeys = _keys_owned_by(c, victim, cfg.num_keys, cfg.zombie_staged_ops)
    history: list[Op] = []
    t = sim.now
    # acked writes through the still-legitimate owner (durable at ack)
    for i, k in enumerate(zkeys):
        inv = t + i * 1e-6
        _rts, ok = c.write(k, f"pre@{k}", victim)
        if ok:
            history.append(Op("write", k, f"pre@{k}", inv, inv + 1e-7))
    # the zombie accepts (but cannot ack) staged ops while partitioned
    t1 = t + cfg.partition_heal_s
    faults.partition(victim, "kn-mnode", start_s=t, end_s=t1)
    sim.log_event("partition", node=victim, net="kn-mnode",
                  heal_s=round(t1, 6))
    for i, k in enumerate(zkeys):
        history.append(Op("write", k, f"zombie@{k}",
                          t + 1e-3 + i * 1e-6, t1, status="fenced"))
    # missed heartbeats: the M-node declares the zombie dead and hands
    # ownership off (this bumps the fence generation past stale_token)
    window = sim.inject_failure(victim)
    result.recovery_window_s = window
    detect_s = next((e.get("detect_s") for e in reversed(sim.event_log)
                     if e["kind"] == "kn_failed"), None)
    # the new owners overwrite half the keys before the zombie returns
    t2 = t + 1e-2
    for i, k in enumerate(zkeys[::2]):
        inv = t2 + i * 1e-6
        _rts, ok = c.write(k, f"own2@{k}")
        if ok:
            history.append(Op("write", k, f"own2@{k}", inv, inv + 1e-7))
    sim.run(min(t1, cfg.duration_s), offered)
    # heal: the zombie flushes its staged oplog with the stale token --
    # every DPM entry point must reject it as a clean no-op
    faults.heal_partitions(victim, t=sim.now)
    sim.log_event("partition_healed", node=victim)
    before = pool.verify_integrity()
    attempts, fenced = 0, 0
    for k in zkeys:
        r = pool.log_write(victim, k, f"zombie@{k}", cfg.value_bytes,
                           token=stale_token)
        attempts += 1
        fenced += isinstance(r, FencedWrite)
    nb = min(4, len(zkeys))
    for op_res in (
        pool.log_write_batch(victim, zkeys[:nb],
                             [f"zombie@{k}" for k in zkeys[:nb]],
                             [cfg.value_bytes] * nb, token=stale_token),
        pool.cas_indirect(zkeys[0], None, 0, kn=victim,
                          token=stale_token),
        pool.recover_kn(victim, token=stale_token),
    ):
        attempts += 1
        fenced += isinstance(op_res, FencedWrite)
    sim.log_event("zombie_flush", node=victim, attempts=attempts,
                  fenced=fenced, token=stale_token)
    result.violations.extend(
        f"zombie: {v}" for v in pool.verify_integrity()
        if v not in before)
    if fenced != attempts:
        result.violations.append(
            f"zombie: {attempts - fenced}/{attempts} stale writes "
            "slipped past the fence")
    sim.run(cfg.duration_s, offered)
    # final reads through the current owners close the history
    t3 = sim.now
    for i, k in enumerate(zkeys):
        inv = t3 + i * 1e-6
        val, _rts, ok = c.read(k)
        if ok:
            history.append(Op("read", k, val, inv, inv + 1e-7))
    verdicts = check_history(history, initial=None)
    bad = sorted(k for k, ok in verdicts.items() if not ok)
    if bad:
        result.violations.append(
            f"zombie: non-linearizable acked history for keys {bad}")
    result.extra = {
        "victim": victim, "stale_token": stale_token,
        "zombie_attempts": attempts, "zombie_fenced": fenced,
        "fenced_write_records": len(pool.fenced_writes),
        "linearizable": not bad, "detect_s": detect_s,
    }


def run_scenario(scenario: str, variant: str, seed: int = 0,
                 smoke: bool = False, model: NetModel | None = None,
                 crash_point: str | None = None,
                 cfg: ScenarioConfig | None = None) -> ScenarioResult:
    """Run one scenario against one variant; returns the SLO row."""
    if scenario not in SCENARIOS + FENCE_SCENARIOS:
        raise ValueError(f"unknown scenario {scenario!r}; "
                         f"choose from {SCENARIOS + FENCE_SCENARIOS}")
    cfg = cfg or (ScenarioConfig.smoke() if smoke else ScenarioConfig())
    model = model or DEFAULT_MODEL
    faults = FaultPlane(seed=seed,
                        drop_flush_rt_rate=cfg.drop_flush_rt_rate,
                        heartbeat_delay_s=cfg.heartbeat_delay_s,
                        heartbeat_jitter_s=cfg.heartbeat_jitter_s)
    c = DinomoCluster(VARIANTS[variant], num_kns=cfg.num_kns,
                      cache_bytes=cfg.cache_bytes,
                      value_bytes=cfg.value_bytes, model=model,
                      num_buckets=cfg.num_buckets,
                      segment_capacity=cfg.segment_capacity,
                      policy=PolicyConfig(epoch_s=cfg.epoch_s,
                                          grace_period_s=cfg.grace_period_s,
                                          max_kns=cfg.max_kns),
                      seed=seed)
    c.load((k, f"v{k}") for k in range(cfg.num_keys))
    c.pool.faults = faults
    mix = "read_mostly_update" if scenario == "storm" \
        else "write_heavy_update"
    base = Workload(num_keys=cfg.num_keys, zipf=0.99, mix=mix,
                    value_bytes=cfg.value_bytes, seed=seed)
    if scenario in ("storm", "composed"):
        wl = StormWorkload(base, base.hot_keys(cfg.storm_hot),
                           cfg.storm_frac, cfg.storm_start_s,
                           cfg.storm_end_s).timed_batched
    else:
        wl = base.timed_batched
    sim = TimedSimulation(c, wl, model=model, dt=cfg.dt,
                          sample_ops=cfg.sample_ops, seed=seed,
                          dataset_bytes=cfg.dataset_bytes, faults=faults)
    offered = _offered_fn(scenario, cfg)
    point = crash_point
    if point is None:
        point = ALL_POINTS[int(faults.rng.integers(0, len(ALL_POINTS)))]
    with_crash = scenario in ("crash", "composed")
    # the partition chaos matrix composes an explicit armed crash point
    # with the open partition; a plain partition run injects no failure
    composed_partition = scenario == "partition" and crash_point is not None
    result = ScenarioResult(
        scenario=scenario, variant=variant, seed=seed,
        crash_point=point if (with_crash or composed_partition) else None,
        duration_s=cfg.duration_s, recovery_window_s=None,
        min_tput_during_frac=None, zero_tput_epochs=0,
        membership_changes=0, replication_actions=0,
        flush_rts_dropped=0, recovery=None)

    if scenario == "partition":
        _run_partition(sim, faults, cfg, offered, result,
                       point=crash_point)
    elif scenario == "zombie":
        _run_zombie(sim, faults, cfg, offered, result)
    elif with_crash:
        sim.run(cfg.crash_at_s, offered)
        t_crash = sim.now
        _crash_and_recover(sim, faults, point, offered, result)
        sim.run(cfg.duration_s, offered)
        # SLO: delivery ratio (throughput / offered) so an oscillating
        # load doesn't masquerade as recovery -- minimum ratio during
        # the recovery window vs the mean ratio just before the crash,
        # plus zero-throughput epochs while the window is open
        window = result.recovery_window_s or 0.0
        obs_end = min(t_crash + max(window, 1.0) + 3 * cfg.dt,
                      cfg.duration_s)
        before = [p.throughput / p.offered for p in sim.trace
                  if t_crash - 6 * cfg.dt <= p.t < t_crash and p.offered > 0]
        during = [p.throughput / p.offered for p in sim.trace
                  if t_crash <= p.t <= obs_end and p.offered > 0]
        if before and during:
            steady = sum(before) / len(before)
            if steady > 0:
                result.min_tput_during_frac = min(during) / steady
        result.zero_tput_epochs = sum(1 for x in during if x <= 0.0)
    else:
        sim.run(cfg.duration_s, offered)

    result.membership_changes = sum(
        1 for r in c.reconfig_log if r["event"] in ("add", "remove",
                                                    "fail"))
    result.replication_actions = sum(
        1 for _t, kind in c.mnode.decision_log
        if kind in ("replicate", "dereplicate"))
    result.flush_rts_dropped = faults.flush_rts_dropped
    # end-of-run health: ring intact, cluster alive, pool consistent
    alive = sim._alive_kns()
    if not alive:
        result.violations.append("end: no alive KNs")
    if not c.ownership.ring.members:
        result.violations.append("end: empty ownership ring")
    result.violations.extend(f"end: {v}" for v in c.pool.verify_integrity())
    # zero throughput at run end is a correctness smell for variants
    # that reconfigure online; shared-nothing reorganizes the whole
    # dataset on any membership change, so a legitimately-open outage
    # window can overlap run end (the paper's Fig. 8 contrast)
    if (sim.trace and sim.trace[-1].throughput <= 0 and not with_crash
            and c.variant.architecture != "shared_nothing"):
        result.violations.append("end: throughput collapsed to zero")
    result.events.extend(_format_events(sim.event_log))
    return result


def _format_events(event_log: list[dict]) -> list[str]:
    """Render schema'd timeline events as human-readable rows."""
    out = []
    for e in event_log:
        rest = " ".join(f"{k}={v}" for k, v in e.items()
                        if k not in ("t", "kind"))
        out.append(f"t={e['t']:.1f} {e['kind']}"
                   + (f" {rest}" if rest else ""))
    return out


# --------------------------------------------------------------------------
# Graceful degradation under sustained overload (the open-loop request
# plane's SLO story): baseline -> 2x-saturation overload -> recovery,
# one continuous run so the overload backlog really drains into the
# recovery phase.  The policy under test: shed lowest-priority traffic
# first, keep latency bounded for admitted ops, return to baseline
# behavior within a bounded settle window once load drops.
# --------------------------------------------------------------------------
def estimated_capacity(model: NetModel, num_kns: int, mix: str,
                       value_bytes: int = 1024,
                       rts_per_op: float = 2.0) -> float:
    """Closed-form saturation estimate used to place open-loop load
    points (the bench reports measured goodput; this only anchors the
    sweep)."""
    r, u, ins = MIXES[mix]
    return model.cluster_throughput(
        num_kns=num_kns, rts_per_op=rts_per_op, value_bytes=value_bytes,
        write_fraction=u + ins)


@dataclass
class OverloadResult:
    """SLO row for one overload run; ``gates`` maps gate name ->
    (passed, observed, bound)."""
    variant: str
    seed: int
    capacity_est: float
    phases: dict
    counters: dict
    gates: dict
    violations: list[str] = field(default_factory=list)

    @property
    def passed(self) -> bool:
        return not self.violations and all(
            ok for ok, _obs, _bound in self.gates.values())

    def row(self) -> dict:
        return {
            "variant": self.variant, "seed": self.seed,
            "capacity_est": self.capacity_est, "phases": self.phases,
            "counters": {k: v for k, v in self.counters.items()},
            "gates": {k: {"passed": ok, "observed": obs, "bound": bound}
                      for k, (ok, obs, bound) in self.gates.items()},
            "violations": self.violations,
        }


def _phase_stats(records, lo: float, hi: float, op_scale: float) -> dict:
    """Latency percentiles + outcome counts for ops that *arrived*
    inside [lo, hi)."""
    lats, completed, shed, failed, total = [], 0, 0, 0, 0
    shed_by_prio: dict[int, int] = {}
    for op in records:
        if not (lo <= op.arrival < hi):
            continue
        total += 1
        if op.status == "completed":
            completed += 1
            lats.append(op.done_t - op.arrival)
        elif op.status == "shed":
            shed += 1
            shed_by_prio[op.priority] = shed_by_prio.get(op.priority,
                                                         0) + 1
        elif op.status == "failed":
            failed += 1
    out = {"offered": total, "completed": completed, "shed": shed,
           "failed": failed, "shed_by_prio": shed_by_prio,
           "goodput": completed / op_scale / max(hi - lo, 1e-9),
           "p50": None, "p99": None, "p999": None}
    if lats:
        p50, p99, p999 = np.percentile(np.asarray(lats),
                                       [50.0, 99.0, 99.9])
        out.update(p50=float(p50), p99=float(p99), p999=float(p999))
    return out


def admitted_latency_bound(cfg: RequestPlaneConfig) -> float:
    """Worst-case client latency of a *completed* request: every
    attempt may burn a full deadline, plus the (jittered) exponential
    backoffs between attempts, plus one engine quantum of slack."""
    n = cfg.max_retries + 1
    backoffs = cfg.backoff_s * (2.0 ** n - 1.0) * 1.25
    return n * cfg.deadline_s + backoffs + 2 * cfg.round_s


def run_overload(variant: str = "dinomo", seed: int = 0,
                 smoke: bool = False, mix: str = "read_mostly_update",
                 num_kns: int = 4, num_keys: int | None = None,
                 plane_cfg: RequestPlaneConfig | None = None,
                 baseline_frac: float = 0.4,
                 overload_frac: float = 2.0,
                 model: NetModel | None = None) -> OverloadResult:
    """One graceful-degradation run: baseline load, sustained
    2x-saturation overload, recovery -- continuous, so the overload
    backlog drains into the recovery window.  Machine-checked gates:

      overload_p999    admitted (completed) ops stay under the
                       retry-closed latency bound during overload
      shed_priority    sheds hit the lowest priority class first
      recovery         post-settle recovery p99 and delivery return to
                       baseline-comparable levels
      exactly_once     no shed / never-dispatched request ID is
                       registered in the durable log; pool integrity
                       holds end-to-end
    """
    model = model or DEFAULT_MODEL
    num_keys = num_keys or (3000 if smoke else 20_000)
    base_s, over_s, rec_s = (0.6, 0.9, 0.9) if smoke else (2.0, 3.0, 3.0)
    settle_s = 0.4 if smoke else 1.0
    cfg = plane_cfg or RequestPlaneConfig()
    c = DinomoCluster(VARIANTS[variant], num_kns=num_kns,
                      cache_bytes=1 << 19, value_bytes=1024, model=model,
                      num_buckets=1 << 13, segment_capacity=256,
                      seed=seed)
    c.load((k, f"v{k}") for k in range(num_keys))
    wl = Workload(num_keys=num_keys, zipf=0.99, mix=mix,
                  value_bytes=1024, seed=seed)
    sim = TimedSimulation(c, wl.timed_batched, model=model, seed=seed)
    cap = estimated_capacity(model, num_kns, mix)
    arrival = PhasedArrival((
        (base_s, ArrivalProcess(rate=baseline_frac * cap)),
        (over_s, ArrivalProcess(rate=overload_frac * cap)),
        (rec_s, ArrivalProcess(rate=baseline_frac * cap)),
    ))
    res = sim.run_open_loop(base_s + over_s + rec_s, arrival, config=cfg)
    recs = res.records or []
    base = _phase_stats(recs, 0.0, base_s, cfg.op_scale)
    over = _phase_stats(recs, base_s, base_s + over_s, cfg.op_scale)
    rec = _phase_stats(recs, base_s + over_s + settle_s,
                       base_s + over_s + rec_s, cfg.op_scale)
    result = OverloadResult(
        variant=variant, seed=seed, capacity_est=cap,
        phases={"baseline": base, "overload": over, "recovery": rec},
        counters={k: v for k, v in res.counters.items()}, gates={})

    # gate: bounded tails for admitted ops under sustained overload
    bound = admitted_latency_bound(cfg)
    p999 = over["p999"]
    result.gates["overload_p999"] = (
        p999 is not None and p999 <= bound, p999, bound)
    # gate: sheds follow priority order (lowest class absorbs the cut)
    sbp = over["shed_by_prio"]
    lowest = cfg.priorities - 1
    low_sheds = sbp.get(lowest, 0)
    high_sheds = sum(v for p, v in sbp.items() if p != lowest)
    total_shed = low_sheds + high_sheds
    result.gates["shed_priority"] = (
        total_shed == 0 or low_sheds > high_sheds,
        {"lowest": low_sheds, "higher": high_sheds}, "lowest > higher")
    # gate: recovery returns to baseline-comparable service after the
    # settle window (tails within 4x baseline p99 or the absolute
    # bound, and delivery ratio back above 95%)
    rec_ok = rec["offered"] > 0 and rec["p99"] is not None
    if rec_ok:
        base_p99 = base["p99"] or bound
        lat_ok = rec["p99"] <= max(4.0 * base_p99, 0.25 * bound)
        deliver = rec["completed"] / rec["offered"]
        rec_ok = lat_ok and deliver >= 0.95
        obs = {"p99": rec["p99"], "delivery": deliver}
    else:
        obs = None
    result.gates["recovery"] = (
        bool(rec_ok), obs,
        {"p99": "<= max(4x baseline, bound/4)", "delivery": ">= 0.95"})
    # gate: exactly-once -- shed / never-dispatched requests left no
    # durable trace, and the pool stays internally consistent
    leaked = 0
    shed_writes = 0
    for op in recs:
        if op.kind != 0 and op.status == "shed":
            shed_writes += 1
            if c.pool.req_applied(op.req_id):
                leaked += 1
    result.gates["exactly_once"] = (
        leaked == 0, {"shed_writes": shed_writes, "leaked": leaked}, 0)
    result.violations.extend(f"overload: {v}"
                             for v in c.pool.verify_integrity())
    return result


def run_suite(variants=BENCH_VARIANTS, scenarios=SCENARIOS, seed: int = 0,
              smoke: bool = False,
              crash_point: str | None = None) -> list[ScenarioResult]:
    """The bench matrix: every scenario x every variant, one seed,
    plus the fencing scenarios for every variant with logical
    ownership (epoch fences are an ownership-plane construct)."""
    rows = [run_scenario(s, v, seed=seed, smoke=smoke,
                         crash_point=crash_point)
            for s in scenarios for v in variants]
    owned = [v for v in variants
             if VARIANTS[v].architecture != "shared_everything"]
    rows.extend(run_scenario(s, v, seed=seed, smoke=smoke)
                for s in FENCE_SCENARIOS for v in owned)
    return rows
