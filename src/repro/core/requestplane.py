"""Open-loop request plane: bounded queues, backpressure, deadlines,
exactly-once retries, and hedged reads over the batched data plane.

The paper's throughput figures are closed-loop: clients wait for each
response, so offered load can never exceed service capacity and tail
latency stays hidden.  Production traffic is open-loop -- requests
arrive on their own schedule (``netmodel.ArrivalProcess``), queue at
their owner KN, and overload shows up as queueing collapse, retry
storms, and unbounded tails unless the serving plane defends itself.
This module adds that defense:

  * **Bounded per-KN FIFO queues** with explicit backpressure.  A full
    queue either *sheds* (reject immediately, lowest priority first --
    a shed request is a clean no-op) or *defers* (push back on the
    client, who resubmits after a short wait), per
    ``RequestPlaneConfig.policy``.
  * **Per-attempt deadlines** with timeout, exponential backoff, and
    bounded retries.  A timed-out write is *indeterminate*: it may have
    applied before the client gave up.  Retries therefore carry the
    original request ID into the durable log (``DinomoCluster.
    execute_batch(req_ids=...)`` -> ``DPMPool.req_index``), so a retry
    of an applied write deduplicates -- exactly-once end to end, across
    crash/recovery boundaries (a torn entry unregisters its ID during
    ``recover_kn``; the retry then applies fresh).
  * **Hedged reads**: a read still waiting ``hedge_after_s`` after
    submission issues a duplicate to the least-loaded other KN (served
    off the shared pool via the miss path) and takes the earlier
    completion.
  * **Timestamps**: every request records queued -> dispatched ->
    completed times; latency percentiles come from these, reconciled
    against the NetModel's RDMA RT costs (Table 5 counts measured live
    off each KN's stats, not assumed).

Simulation scaling: the engine op-scales the open-loop system by
``op_scale`` -- arrivals run at ``rate * op_scale`` and each KN drains
its queue at ``kn_capacity * op_scale`` sim-ops/s -- so utilization
(and therefore queueing behavior) matches the real system while the
Python data plane executes a tractable number of ops.  Queue waits are
``depth / (capacity * op_scale)`` and come out in real seconds; the
in-service time of an op is its real, unscaled ``NetModel.
service_time`` from measured RTs/op.  Every sampled op runs against
the real data structures through ``execute_batch``, so hit ratios,
RTs/op, crashes, and recovery are measured, not assumed.
"""

from __future__ import annotations

import dataclasses
import heapq
import math
from collections import deque

import numpy as np

from .faults import KNCrash
from .netmodel import ArrivalProcess, DEFAULT_MODEL, NetModel

# terminal request statuses
COMPLETED = "completed"      # client got a success before some deadline
SHED = "shed"                # rejected by backpressure: clean no-op
FAILED = "failed"            # retries exhausted (writes: indeterminate)
INFLIGHT = "inflight"        # censored at end of run

_INF = math.inf


@dataclasses.dataclass(frozen=True)
class RequestPlaneConfig:
    """Knobs for the open-loop request plane (times in real seconds,
    queue sizes in sim-ops -- one sim-op stands for ``1 / op_scale``
    real ops, see the module docstring)."""

    queue_capacity: int = 32          # per-KN bounded FIFO (sim-ops)
    policy: str = "shed"              # queue-full: "shed" | "defer"
    deadline_s: float = 0.03          # per-attempt deadline budget
    max_retries: int = 3
    backoff_s: float = 5e-3           # exponential base, 25% jitter
    hedge_after_s: float | None = None
    priorities: int = 2               # 0 == highest
    priority_weights: tuple | None = None
    op_scale: float = 1e-3            # sim-ops per real op
    round_s: float = 0.02             # batching quantum of the engine
    defer_wait_s: float = 5e-3        # client resubmit wait on defer
    dedup_rts: float = 1.0            # req-index probe cost on dedup hit
    record_values: bool = False       # collect read values (history mode)
    keep_records: bool = True         # retain per-op records

    def __post_init__(self):
        if self.policy not in ("shed", "defer"):
            raise ValueError(f"unknown queue-full policy {self.policy!r}")
        if self.priorities < 1:
            raise ValueError("need at least one priority class")
        if self.op_scale <= 0.0:
            raise ValueError("op_scale must be positive")


@dataclasses.dataclass
class OpRecord:
    """One logical client request across all its attempts."""
    req_id: int
    kind: int                 # 0 read, 1 write, 2 delete
    key: int
    priority: int
    arrival: float            # original submission time
    payload: str | None
    submit_t: float = 0.0     # current attempt's submission
    deadline: float = 0.0     # current attempt's deadline
    enq_t: float = 0.0
    attempts: int = 0
    deferrals: int = 0
    dispatch_t: float = -1.0  # current attempt's dispatch (-1 = queued)
    first_dispatch_t: float = -1.0
    status: str = INFLIGHT
    done_t: float = -1.0
    value: object = None      # read result (history mode)
    kn: str | None = None
    dispatched_ever: bool = False   # any attempt reached the data plane
    hedged: bool = False
    hedge_win: bool = False
    deduped: bool = False


class _KnQueue:
    """Bounded multi-priority FIFO for one KN (strict priority
    dispatch, FIFO within a class)."""

    __slots__ = ("qs", "count")

    def __init__(self, priorities: int):
        self.qs = [deque() for _ in range(priorities)]
        self.count = 0

    def peek(self) -> OpRecord | None:
        for q in self.qs:
            if q:
                return q[0]
        return None

    def pop(self) -> OpRecord:
        for q in self.qs:
            if q:
                self.count -= 1
                return q.popleft()
        raise IndexError("pop from empty queue")

    def push(self, op: OpRecord) -> None:
        self.qs[op.priority].append(op)
        self.count += 1

    def evict_lower(self, priority: int) -> OpRecord | None:
        """Evict the youngest *sheddable* op of the lowest class
        strictly below ``priority`` (shed policy: lowest-priority
        traffic goes first).  An op any of whose attempts reached the
        data plane is never sheddable -- shed promises a clean no-op,
        and a requeued retry's earlier attempt may already have applied
        (its timeout was indeterminate)."""
        for pi in range(len(self.qs) - 1, priority, -1):
            q = self.qs[pi]
            for i in range(len(q) - 1, -1, -1):
                if not q[i].dispatched_ever:
                    victim = q[i]
                    del q[i]
                    self.count -= 1
                    return victim
        return None

    def expire(self, t: float) -> list[OpRecord]:
        """Remove (and return) queued ops whose deadline is <= t."""
        out = []
        for pi, q in enumerate(self.qs):
            if not any(op.deadline <= t for op in q):
                continue
            keep = deque()
            for op in q:
                (out if op.deadline <= t else keep).append(op)
            self.qs[pi] = keep
        self.count -= len(out)
        return out


@dataclasses.dataclass
class RequestPlaneResult:
    duration_s: float
    offered_rate: float            # real ops/s (long-run mean)
    op_scale: float
    counters: dict
    latencies: np.ndarray          # completed-op client latencies (s)
    records: list | None
    events: list

    def percentiles(self) -> dict:
        if self.latencies.size == 0:
            return {"p50": None, "p99": None, "p999": None}
        p50, p99, p999 = np.percentile(self.latencies, [50.0, 99.0, 99.9])
        return {"p50": float(p50), "p99": float(p99), "p999": float(p999)}

    def goodput(self) -> float:
        """Completed real ops/s over the offered-load window."""
        if self.duration_s <= 0:
            return 0.0
        return self.counters["completed"] / self.op_scale / self.duration_s

    def row(self) -> dict:
        pct = self.percentiles()
        return {
            "duration_s": self.duration_s,
            "offered_rate": self.offered_rate,
            "op_scale": self.op_scale,
            "goodput": self.goodput(),
            **pct,
            "counters": dict(self.counters),
        }


class RequestPlane:
    """The open-loop engine: one run drives ``cluster`` with arrivals
    from ``arrival`` (an ``ArrivalProcess`` or anything with
    ``.arrivals(rng, t0, t1)`` + ``.scaled(f)``), sampling op kinds and
    keys from ``workload(t, rng, n)`` (the ``TimedSimulation``
    convention: a (kinds, keys) array pair or a list of (kind, key))."""

    def __init__(self, cluster, arrival, workload, *,
                 cfg: RequestPlaneConfig | None = None,
                 model: NetModel = DEFAULT_MODEL, seed: int = 0,
                 t0: float = 0.0, event_sink: list | None = None,
                 on_crash=None):
        self.c = cluster
        self.cfg = cfg = cfg or RequestPlaneConfig()
        self.model = model
        self.offered_rate = float(getattr(arrival, "rate", 0.0))
        self.arrival = arrival.scaled(cfg.op_scale)
        self.workload = workload
        self.rng = np.random.default_rng(seed)
        self.t0 = t0
        self.on_crash = on_crash
        self.events: list[dict] = [] if event_sink is None else event_sink
        self.queues: dict[str, _KnQueue] = {}
        self.free_at: dict[str, float] = {}
        self.rts_est: dict[str, float] = {}    # EWMA measured RTs/op
        self.credit: dict[str, float] = {}     # server busy time / sim-op
        self.pending: list = []                # (t, seq, op) resubmissions
        self.records: list[OpRecord] = []
        self.latencies: list[float] = []
        self.never_applied_reqs: list[int] = []  # shed / never-dispatched
        # write requests that could still retry (req_id -> None); the
        # min is the retry horizon below which the pool's dedup table
        # can be compacted (DPMPool.retire_reqs) -- see _retire_reqs
        self._open_writes: set[int] = set()
        self.retire_horizon = 0                # last _retire_reqs horizon
        self._seq = 0
        self._next_id = 0
        self._round_end = t0
        z = ["offered", "resubmits", "completed", "shed", "deferred",
             "queue_expired", "late_applied", "attempt_timeouts",
             "retries", "dedup_hits", "hedges", "hedge_wins", "failed",
             "crashes", "executed", "refused", "censored",
             "retired_reqs"]
        self.counters: dict = {k: 0 for k in z}
        self.counters["shed_by_prio"] = [0] * cfg.priorities
        self.counters["completed_by_prio"] = [0] * cfg.priorities
        self._refresh_credit()

    # ----- bookkeeping ----------------------------------------------------
    def _log(self, kind: str, t: float, **fields) -> None:
        self.events.append({"t": round(t, 6), "kind": kind, **fields})

    def _tick(self) -> int:
        self._seq += 1
        return self._seq

    def _refresh_credit(self) -> None:
        """Per-KN sim service credit from the current RTs/op estimate:
        1 / (kn_capacity * op_scale) seconds of server occupancy per
        sim-op (the op-scaled drain rate; see the module docstring)."""
        vb = self.c.value_bytes
        for nm in self.c.kns:
            est = self.rts_est.get(nm, 2.0)
            mu = self.model.kn_capacity(max(est, 0.5), vb) \
                * self.cfg.op_scale
            self.credit[nm] = 1.0 / max(mu, 1e-9)

    def _sample(self, t: float, n: int):
        ops = self.workload(t, self.rng, n)
        if isinstance(ops, tuple):
            return ops
        kinds = np.fromiter((0 if k == "read" else 1 for k, _ in ops),
                            np.uint8, len(ops))
        keys = np.fromiter((key for _, key in ops), np.int64, len(ops))
        return kinds, keys

    def _priorities(self, n: int) -> np.ndarray:
        P = self.cfg.priorities
        if P == 1:
            return np.zeros(n, np.int64)
        w = self.cfg.priority_weights
        if w is None:
            return self.rng.integers(0, P, n)
        p = np.asarray(w, np.float64)
        return self.rng.choice(P, size=n, p=p / p.sum())

    # ----- driver ---------------------------------------------------------
    def run(self, duration: float) -> RequestPlaneResult:
        t, t_end = self.t0, self.t0 + duration
        while t < t_end:
            t1 = min(t + self.cfg.round_s, t_end)
            self._round(t, t1, fresh=True)
            t = t1
        # drain phase: no fresh arrivals; resolve queued ops and
        # scheduled retries (bounded -- retries are finite)
        cfg = self.cfg
        drain_cap = t + (cfg.max_retries + 1) \
            * (cfg.deadline_s + 8 * cfg.backoff_s) + 4 * cfg.round_s
        while (self.pending
               or any(q.count for q in self.queues.values())) \
                and t < drain_cap:
            t1 = t + cfg.round_s
            self._round(t, t1, fresh=False)
            t = t1
        for op in self.records:
            if op.status == INFLIGHT:
                self.counters["censored"] += 1
        return RequestPlaneResult(
            duration_s=duration, offered_rate=self.offered_rate,
            op_scale=cfg.op_scale, counters=self.counters,
            latencies=np.asarray(self.latencies, np.float64),
            records=self.records if cfg.keep_records else None,
            events=self.events)

    def _round(self, rt0: float, t1: float, fresh: bool) -> None:
        self._round_end = t1
        cfg = self.cfg
        per_kn: dict[str, list[OpRecord]] = {}
        sheds0 = self.counters["shed"]
        if fresh:
            ts = self.arrival.arrivals(self.rng, rt0, t1)
            n = int(ts.size)
            if n:
                kinds, keys = self._sample(rt0, n)
                prios = self._priorities(n)
                for i in range(n):
                    rid = self._next_id
                    self._next_id += 1
                    kd = int(kinds[i])
                    op = OpRecord(req_id=rid, kind=kd, key=int(keys[i]),
                                  priority=int(prios[i]),
                                  arrival=float(ts[i]),
                                  payload=f"r{rid}" if kd else None)
                    op.submit_t = op.arrival
                    op.deadline = op.arrival + cfg.deadline_s
                    op.attempts = 1
                    if kd:
                        self._open_writes.add(rid)
                    self.counters["offered"] += 1
                    if cfg.keep_records:
                        self.records.append(op)
                    self._submit(op, per_kn)
        while self.pending and self.pending[0][0] < t1:
            _, _, op = heapq.heappop(self.pending)
            self.counters["resubmits"] += 1
            self._submit(op, per_kn)
        dispatches: list[OpRecord] = []
        for nm in sorted(set(per_kn)
                         | {k for k, q in self.queues.items() if q.count}):
            arr = per_kn.get(nm, ())
            if arr:
                arr = sorted(arr, key=lambda o: o.submit_t)
            self._drain_kn(nm, arr, t1, dispatches)
        if dispatches:
            dispatches.sort(key=lambda o: o.dispatch_t)
            self._resolve_batch(dispatches)
        shed = self.counters["shed"] - sheds0
        if shed:
            self._log("shed", t1, count=shed, policy=cfg.policy)
        self._retire_reqs()

    def _retire_reqs(self) -> None:
        """Per-round dedup-table compaction.  The retry horizon is the
        smallest request ID a future ``req_applied`` probe could still
        carry: the min over writes that are not yet terminal (every
        probe comes from a retry of such a write).  Everything below it
        is provably dead to the exactly-once contract and can leave
        ``DPMPool.req_index`` -- including across crash/recover, since
        a recovered pool is only ever probed by those same open
        retries."""
        horizon = min(self._open_writes) if self._open_writes \
            else self._next_id
        self.retire_horizon = horizon
        self.counters["retired_reqs"] += self.c.pool.retire_reqs(horizon)

    # ----- admission ------------------------------------------------------
    def _submit(self, op: OpRecord, per_kn: dict) -> None:
        try:
            nm = self.c.route(op.key)
        except KeyError:
            self._fail(op, op.submit_t)
            return
        kn = self.c.kns.get(nm)
        if kn is None or not (kn.alive and kn.available):
            # owner down: the client sees a refusal and retries later
            self.counters["refused"] += 1
            self._attempt_timeout(op, op.submit_t)
            return
        op.kn = nm
        per_kn.setdefault(nm, []).append(op)

    def _enqueue(self, nm: str, op: OpRecord) -> None:
        q = self.queues.get(nm)
        if q is None:
            q = self.queues[nm] = _KnQueue(self.cfg.priorities)
        if q.count >= self.cfg.queue_capacity:
            # backpressure: shedding is only legal for first attempts
            # (a shed request must be a clean no-op, and an earlier
            # attempt of a retry may already have applied) -- retries
            # under a full queue always defer
            if self.cfg.policy == "defer" or op.attempts > 1:
                self._defer(op)
                return
            victim = q.evict_lower(op.priority)
            if victim is not None:
                self._shed(victim, op.submit_t)
                op.enq_t = op.submit_t
                q.push(op)
            else:
                self._shed(op, op.submit_t)
            return
        op.enq_t = op.submit_t
        q.push(op)

    def _defer(self, op: OpRecord) -> None:
        op.deferrals += 1
        self.counters["deferred"] += 1
        t = op.submit_t + self.cfg.defer_wait_s
        if t >= op.deadline:
            # the client's timer fires before the resubmission lands
            self._attempt_timeout(op, op.deadline)
            return
        op.submit_t = t
        heapq.heappush(self.pending, (t, self._tick(), op))

    def _shed(self, op: OpRecord, t: float) -> None:
        op.status = SHED
        op.done_t = t
        self._open_writes.discard(op.req_id)
        self.counters["shed"] += 1
        self.counters["shed_by_prio"][op.priority] += 1
        if op.kind != 0 and not op.dispatched_ever:
            self.never_applied_reqs.append(op.req_id)

    # ----- dispatch -------------------------------------------------------
    def _drain_kn(self, nm: str, arrivals, t1: float,
                  dispatches: list[OpRecord]) -> None:
        """Interleave this round's arrivals with the KN's queue drain in
        event-time order; collect dispatched ops for the batch."""
        q = self.queues.get(nm)
        if q is None:
            q = self.queues[nm] = _KnQueue(self.cfg.priorities)
        free = self.free_at.get(nm, self.t0)
        credit = self.credit.get(nm)
        if credit is None:
            self._refresh_credit()
            credit = self.credit.get(nm, 1e-3)
        ai, na = 0, len(arrivals)
        while True:
            head = q.peek()
            next_arr = arrivals[ai].submit_t if ai < na else _INF
            if head is not None:
                dis_t = max(free, head.enq_t)
                if dis_t <= next_arr and dis_t < t1:
                    op = q.pop()
                    dis_t = max(free, op.enq_t)
                    if dis_t >= op.deadline:
                        self._queue_expired(op)
                        continue
                    op.dispatch_t = dis_t
                    if op.first_dispatch_t < 0:
                        op.first_dispatch_t = dis_t
                    if (self.cfg.hedge_after_s is not None
                            and op.kind == 0
                            and dis_t - op.submit_t
                            >= self.cfg.hedge_after_s):
                        op.hedged = True
                    free = dis_t + credit
                    dispatches.append(op)
                    continue
            if next_arr < t1:
                self._enqueue(nm, arrivals[ai])
                ai += 1
                continue
            break
        self.free_at[nm] = free
        for op in q.expire(t1):
            self._queue_expired(op)

    def _queue_expired(self, op: OpRecord) -> None:
        """An op's deadline passed while it sat in the queue -- the
        attempt never reached the data plane."""
        self.counters["queue_expired"] += 1
        if op.hedged is False and op.kind == 0 \
                and self.cfg.hedge_after_s is not None \
                and op.submit_t + self.cfg.hedge_after_s < op.deadline:
            done = self._issue_hedge(op, op.submit_t
                                     + self.cfg.hedge_after_s)
            if done is not None and done <= op.deadline:
                op.hedged = op.hedge_win = True
                self.counters["hedge_wins"] += 1
                self._complete(op, done)
                return
        self._attempt_timeout(op, op.deadline)

    def _issue_hedge(self, op: OpRecord, t_issue: float) -> float | None:
        """Model a duplicate read on the least-loaded other KN: it
        occupies that KN's service credit and completes via the miss
        path (index probe + value fetch on top of the owner's RT
        estimate -- the hedge target serves off the shared pool)."""
        best, bt = None, _INF
        for nm, kn in self.c.kns.items():
            if nm == op.kn or not (kn.alive and kn.available):
                continue
            ft = self.free_at.get(nm, self.t0)
            if ft < bt:
                best, bt = nm, ft
        if best is None:
            return None
        self.counters["hedges"] += 1
        disp = max(t_issue, bt)
        self.free_at[best] = disp + self.credit.get(best, 1e-3)
        rts = self.rts_est.get(best, 2.0) + 2.0
        return disp + self.model.service_time(rts)

    # ----- execution ------------------------------------------------------
    def _resolve_batch(self, dispatches: list[OpRecord]) -> None:
        pool = self.c.pool
        run: list[OpRecord] = []
        for op in dispatches:
            op.dispatched_ever = True
            if op.kind != 0 and op.attempts > 1 \
                    and pool.req_applied(op.req_id):
                # an earlier attempt of this write durably applied: the
                # retry deduplicates against the staged oplog instead of
                # re-executing (exactly-once)
                op.deduped = True
                self.counters["dedup_hits"] += 1
                done = op.dispatch_t \
                    + self.model.service_time(self.cfg.dedup_rts)
                self._settle(op, done)
            else:
                run.append(op)
        if not run:
            return
        n = len(run)
        kinds = np.fromiter((op.kind for op in run), np.uint8, n)
        keys = np.fromiter((op.key for op in run), np.int64, n)
        rids = np.fromiter((op.req_id if op.kind else -1 for op in run),
                           np.int64, n)
        payloads = [op.payload for op in run]
        self.c.reset_stats()
        self.counters["executed"] += n
        try:
            res = self.c.execute_batch(
                kinds, keys, values=lambda i: payloads[i], req_ids=rids,
                collect_values=self.cfg.record_values)
        except KNCrash as e:
            self._handle_crash(e, run)
            return
        # measured RTs/op per KN this round (Table 5 reconciliation:
        # service times come from the live RT counters, not a constant)
        fp = getattr(self.c.pool, "faults", None)
        for nm, kn in self.c.kns.items():
            st = kn.stats
            if st.ops:
                meas = st.rts / st.ops
                if fp is not None:
                    # a gray (fail-slow) KN serves correctly but slowly:
                    # its measured RTs inflate, so the EWMA -> credits ->
                    # hedging machinery sees the degradation organically
                    meas *= fp.slow_factor(nm, self._round_end)
                prev = self.rts_est.get(nm)
                self.rts_est[nm] = meas if prev is None \
                    else 0.7 * prev + 0.3 * meas
        self._refresh_credit()
        vals = res.values if self.cfg.record_values else None
        for i, op in enumerate(run):
            rts = self.rts_est.get(op.kn, 2.0)
            done = op.dispatch_t + self.model.service_time(rts)
            if vals is not None and op.kind == 0:
                op.value = vals[i]
            if op.hedged:
                hd = self._issue_hedge(
                    op, op.submit_t + self.cfg.hedge_after_s)
                if hd is not None and hd < done:
                    op.hedge_win = True
                    self.counters["hedge_wins"] += 1
                    done = hd
            self._settle(op, done)

    def _settle(self, op: OpRecord, done: float) -> None:
        if done <= op.deadline:
            self._complete(op, done)
            return
        # the attempt applied (or executed) but the client's timer fired
        # first: an indeterminate timeout from the client's view
        self.counters["late_applied"] += 1
        self._attempt_timeout(op, op.deadline)

    def _handle_crash(self, e: KNCrash, run: list[OpRecord]) -> None:
        """A KN fail-stopped mid-batch: every in-flight op of the batch
        is indeterminate (some prefix durably applied, the rest did
        not).  Clients time out and retry; write retries deduplicate
        against whatever the recovery plane kept, so each request still
        applies exactly once."""
        self.counters["crashes"] += 1
        self._log("kn_crash", self._round_end, node=e.kn, point=e.point)
        handler = self.on_crash or RequestPlane.default_recover
        handler(self, e)
        for op in run:
            self._attempt_timeout(op, op.deadline)

    @staticmethod
    def default_recover(plane: "RequestPlane", e: KNCrash) -> None:
        """Transient crash + immediate crash-consistent recovery: run
        ``DPMPool.recover_kn`` (torn tails discarded, their request IDs
        unregistered, sealed-but-unmerged entries replayed) and charge
        the detection window to the victim's serving clock.  Scenarios
        that want full failover pass their own ``on_crash``."""
        pool = plane.c.pool
        if pool.faults is not None and pool.faults.armed:
            pool.faults.disarm()
        pool.recover_kn(e.kn)
        t = max(plane.free_at.get(e.kn, plane.t0), plane._round_end)
        plane.free_at[e.kn] = t + plane.model.detect_s
        plane._log("kn_recovered", plane._round_end, node=e.kn)

    # ----- outcomes -------------------------------------------------------
    def _complete(self, op: OpRecord, done: float) -> None:
        op.status = COMPLETED
        op.done_t = done
        self._open_writes.discard(op.req_id)
        self.counters["completed"] += 1
        self.counters["completed_by_prio"][op.priority] += 1
        self.latencies.append(done - op.arrival)

    def _attempt_timeout(self, op: OpRecord, t_detect: float) -> None:
        self.counters["attempt_timeouts"] += 1
        if op.attempts > self.cfg.max_retries:
            self._fail(op, t_detect)
            return
        self.counters["retries"] += 1
        back = self.cfg.backoff_s * (2.0 ** (op.attempts - 1))
        back *= 1.0 + 0.25 * float(self.rng.random())
        op.attempts += 1
        op.submit_t = t_detect + back
        op.deadline = op.submit_t + self.cfg.deadline_s
        op.dispatch_t = -1.0
        heapq.heappush(self.pending, (op.submit_t, self._tick(), op))

    def _fail(self, op: OpRecord, t: float) -> None:
        op.status = FAILED
        op.done_t = t
        self._open_writes.discard(op.req_id)
        self.counters["failed"] += 1
        if op.kind != 0 and not op.dispatched_ever:
            self.never_applied_reqs.append(op.req_id)

    # ----- linearizability history ----------------------------------------
    def history(self) -> list:
        """The run as a linearizability history (``core.
        linearizability.Op``), honoring indeterminacy:

          * completed ops are definite (reads only meaningful with
            ``record_values=True``; hedge-win reads are skipped -- the
            modeled hedge returns no value);
          * failed/censored *writes that reached the data plane* are
            indeterminate (``status="maybe"``: the checker may include
            or exclude them);
          * shed and never-dispatched ops are guaranteed no-ops and are
            excluded (their request IDs are in ``never_applied_reqs``
            for the no-op assertion)."""
        from .linearizability import Op
        out = []
        for op in self.records:
            if op.status == COMPLETED:
                if op.kind == 0:
                    if self.cfg.record_values and not op.hedge_win:
                        out.append(Op("read", op.key, op.value,
                                      op.arrival, op.done_t))
                elif op.kind == 1:
                    out.append(Op("write", op.key, op.payload,
                                  op.arrival, op.done_t))
                else:
                    out.append(Op("write", op.key, None,
                                  op.arrival, op.done_t))
            elif op.status in (FAILED, INFLIGHT) and op.kind != 0 \
                    and op.dispatched_ever:
                val = op.payload if op.kind == 1 else None
                out.append(Op("write", op.key, val, op.arrival, _INF,
                              status="maybe"))
        return out
