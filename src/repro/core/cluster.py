"""The DINOMO cluster: clients -> RNs -> KNs -> DPM pool (paper Fig. 1).

This is the functional simulator: every request actually runs against
the real data structures (DAC caches, CLHT index, log segments,
indirection table), and the exact number of network round trips is
accounted per operation -- the paper's primary cost metric (Tables 5/6).
Wall-clock figures are derived from RT counts via core.netmodel.

Four system variants share this machinery (paper Sec. 5):
  dinomo    OP + DAC + selective replication          (the paper's system)
  dinomo-s  OP + shortcut-only cache                  (isolates DAC's benefit)
  dinomo-n  shared-nothing + DAC                      (AsymNVM stand-in)
  clover    shared-everything + shortcut-only cache   (state of the art)
"""

from __future__ import annotations

import heapq
import itertools
import random
from collections import OrderedDict
from dataclasses import dataclass, field

import numpy as np

from .dac import ArrayDAC, DAC, StaticCache, CacheStats
from .dpm_pool import DPMPool
from .mnode import PolicyConfig, PolicyEngine
from .netmodel import NetModel, DEFAULT_MODEL
from .hashring import stable_hash
from .ownership import OwnershipMap, ReconfigEvent


@dataclass(frozen=True)
class VariantConfig:
    name: str
    cache_policy: str          # "dac" | "shortcut" | "value" | "static:<f>" | "clover"
    architecture: str          # "op" | "shared_nothing" | "shared_everything"
    selective_replication: bool


DINOMO = VariantConfig("dinomo", "dac", "op", True)
DINOMO_S = VariantConfig("dinomo-s", "shortcut", "op", True)
DINOMO_N = VariantConfig("dinomo-n", "dac", "shared_nothing", False)
CLOVER = VariantConfig("clover", "clover", "shared_everything", False)
VARIANTS = {v.name: v for v in (DINOMO, DINOMO_S, DINOMO_N, CLOVER)}


def make_cache(policy: str, capacity_bytes: int, reference: bool = False):
    if policy == "dac":
        # array-backed DAC: decision-for-decision equivalent to the
        # reference DAC (property-tested), built for the batched data
        # plane. ``reference=True`` selects the unoptimized oracle --
        # used by equivalence tests and as the bench baseline.
        return DAC(capacity_bytes) if reference \
            else ArrayDAC(capacity_bytes)
    if policy == "shortcut":
        return StaticCache(capacity_bytes, 0.0)
    if policy == "value":
        return StaticCache(capacity_bytes, 1.0)
    if policy.startswith("static:"):
        return StaticCache(capacity_bytes, float(policy.split(":")[1]))
    if policy == "clover":
        return CloverCache(capacity_bytes)
    raise ValueError(f"unknown cache policy {policy!r}")


class CloverCache:
    """Clover KNs keep a shortcut-only cache whose entries can go stale:
    out-of-place updates grow a version chain that readers must walk."""

    def __init__(self, capacity_bytes: int, entry_bytes: int = 32):
        self.cap_entries = max(capacity_bytes // entry_bytes, 1)
        self.entries: OrderedDict[int, int] = OrderedDict()  # key -> version
        self.stats = CacheStats()

    def lookup(self, key: int):
        v = self.entries.get(key)
        if v is None:
            self.stats.misses += 1
            return None
        self.entries.move_to_end(key)
        self.stats.shortcut_hits += 1
        return v

    def fill(self, key: int, version: int):
        self.entries[key] = version
        self.entries.move_to_end(key)
        while len(self.entries) > self.cap_entries:
            self.entries.popitem(last=False)
            self.stats.evictions += 1

    def clear(self):
        self.entries.clear()


@dataclass
class KNStats:
    ops: int = 0
    rts: float = 0.0
    reads: int = 0
    writes: int = 0
    write_stalls: int = 0
    refused: int = 0

    def reset_window(self):
        self.ops = 0
        self.rts = 0.0
        self.reads = 0
        self.writes = 0


@dataclass
class BatchResult:
    """What a batched execution observed (aggregates the scalar loop
    would have produced; per-op stats land in kn.stats / cache.stats)."""
    executed: int                  # ops that reached a KN (incl. refused)
    writes: int                    # write attempts among them
    per_kn: dict[str, int]         # executed ops per KN name
    executed_keys: np.ndarray      # keys of executed ops, in order
    values: list | None = None     # read results iff collect_values


class KVSNode:
    """One KN: cache + exclusive log + soft ownership state."""

    def __init__(self, name: str, variant: VariantConfig, cache_bytes: int,
                 pool: DPMPool, write_batch: int = 8,
                 segcache_segments: int = 4, reference_cache: bool = False):
        self.name = name
        self.variant = variant
        self.cache = make_cache(variant.cache_policy, cache_bytes,
                                reference=reference_cache)
        self.pool = pool
        self.write_batch = write_batch
        self._pending_flush = 0
        # committed/un-merged segments cached locally (paper Sec. 4):
        # keys here are readable with zero RTs at the writing KN.
        self.segcache: OrderedDict[int, tuple[int, int]] = OrderedDict()
        self.segcache_cap = segcache_segments * pool.segment_capacity
        self.stats = KNStats()
        self.alive = True
        self.available = True      # False while participating in a reconfig

    # ----- helpers ---------------------------------------------------------
    def _segcache_put(self, key: int, ptr: int, length: int):
        self.segcache[key] = (ptr, length)
        self.segcache.move_to_end(key)
        while len(self.segcache) > self.segcache_cap:
            self.segcache.popitem(last=False)

    def flush_rts(self) -> float:
        """Amortized one-sided log-write cost: one RT per batch."""
        self._pending_flush += 1
        if self._pending_flush >= self.write_batch:
            self._pending_flush = 0
            return 1.0
        return 0.0

    def clear_soft_state(self):
        self.cache.clear()
        self.segcache.clear()


class DinomoCluster:
    """End-to-end cluster with exact RT accounting."""

    def __init__(self, variant: VariantConfig = DINOMO, num_kns: int = 4,
                 cache_bytes: int = 1 << 20, value_bytes: int = 1024,
                 model: NetModel = DEFAULT_MODEL,
                 policy: PolicyConfig | None = None,
                 num_buckets: int = 1 << 18, segment_capacity: int = 2048,
                 vnodes: int = 64, seed: int = 0,
                 reference_cache: bool = False):
        self.variant = variant
        # reference_cache selects the unoptimized per-op DAC oracle
        # (the batched plane then runs the fused per-op fallback)
        self.reference_cache = reference_cache
        self.model = model
        self.value_bytes = value_bytes
        self.cache_bytes = cache_bytes
        self.pool = DPMPool(num_buckets=num_buckets,
                            segment_capacity=segment_capacity)
        self.ownership = OwnershipMap(vnodes=vnodes)
        self.kns: dict[str, KVSNode] = {}
        self.mnode = PolicyEngine(policy or PolicyConfig())
        self.rng = random.Random(seed)
        self._kn_counter = 0
        self._seq = 0
        # Clover: per-key version counters + metadata-server op count
        self.versions: dict[int, int] = {}
        self.ms_ops = 0
        self.reconfig_log: list[dict] = []
        for _ in range(num_kns):
            self.add_kn(record=False)

    # ---------------------------------------------------------------------
    # membership
    # ---------------------------------------------------------------------
    def _new_kn_name(self) -> str:
        self._kn_counter += 1
        return f"kn{self._kn_counter}"

    def add_kn(self, record: bool = True) -> tuple[str, ReconfigEvent | None]:
        name = self._new_kn_name()
        self.pool.register_kn(name)
        self.kns[name] = KVSNode(name, self.variant, self.cache_bytes,
                                 self.pool,
                                 reference_cache=self.reference_cache)
        ev = self.ownership.add_kn(name)
        cost = self._reconfigure(ev) if record else None
        return name, ev if record else None

    def remove_kn(self, name: str) -> ReconfigEvent:
        ev = self.ownership.remove_kn(name)
        self._reconfigure(ev)
        self.pool.drop_kn(name)
        del self.kns[name]
        return ev

    def fail_kn(self, name: str) -> ReconfigEvent:
        """Fail-stop KN failure: DRAM (cache) contents lost; its pending
        log segments survive in DPM and are merged by a peer."""
        kn = self.kns[name]
        kn.alive = False
        kn.clear_soft_state()          # DRAM lost
        ev = self.ownership.remove_kn(name, failed=True)
        self._reconfigure(ev, failed=name)
        del self.kns[name]
        return ev

    def _reconfigure(self, ev: ReconfigEvent, failed: str | None = None):
        """Paper Sec. 3.5 seven-step protocol. Returns a cost record with
        the synchronous-merge size (netmodel converts to seconds).

        Steps: (1) identify participants, (2) participants unavailable,
        (3) synchronously merge their pending logs, (4) new mapping,
        (5) participants available (others already serving; wrongly
        routed requests are refused), (6)/(7) async propagation."""
        participants = [p for p in ev.participants if p in self.kns]
        for p in participants:
            self.kns[p].available = False                 # step 2
        merged = 0
        if failed is not None:
            merged += self.pool.merge_all(failed)         # peer merges
            self.pool.drop_kn(failed)
        for p in participants:
            merged += self.pool.merge_all(p)              # step 3
        moved_fraction = 0.0
        if self.variant.architecture == "shared_nothing":
            # AsymNVM-style: physical data reorganization is required.
            moved_fraction = 1.0 / max(len(self.kns), 1)
        for p in participants:
            if self.kns[p].alive:
                self.kns[p].clear_soft_state()            # ownership moved
                self.kns[p].available = True              # step 5
        # durable policy metadata so restarted nodes can rebuild
        self.pool.policy_metadata["ownership"] = self.ownership.snapshot_blob()
        rec = {"event": ev.kind, "node": ev.node,
               "participants": sorted(ev.participants),
               "merged_entries": merged,
               "moved_fraction": moved_fraction,
               "version": ev.new_version}
        self.reconfig_log.append(rec)
        return rec

    # ---------------------------------------------------------------------
    # selective replication mechanics (policy lives in mnode)
    # ---------------------------------------------------------------------
    def replicate_key(self, key: int, factor: int) -> None:
        if not self.variant.selective_replication:
            return
        # pending log entries for this key must reach the index before
        # the indirection slot snapshots it (paper: merge-before-share)
        for owner in self.ownership.owners(key):
            if owner in self.kns:
                self.pool.merge_all(owner)
        self.pool.install_indirect(key)
        owners = self.ownership.replicate(key, factor)
        # indirect pointers forbid value caching (paper Sec. 5.3)
        for o in owners:
            if o in self.kns:
                self.kns[o].cache.demote_to_shortcut(key)

    def dereplicate_key(self, key: int) -> None:
        for o in self.ownership.owners(key):
            if o in self.kns:
                self.kns[o].cache.invalidate(key)
        self.ownership.dereplicate(key)
        self.pool.remove_indirect(key)

    # ---------------------------------------------------------------------
    # request execution. Returns RTs charged (floats: write RTs amortize).
    # ---------------------------------------------------------------------
    def route(self, key: int) -> str:
        if self.variant.architecture == "shared_everything":
            # any KN serves any key: clients spread requests uniformly
            names = [n for n, k in self.kns.items() if k.alive]
            return self.rng.choice(names)
        owners = [o for o in self.ownership.owners(key) if o in self.kns]
        if not owners:
            raise KeyError("no owner")
        return owners[0] if len(owners) == 1 else self.rng.choice(owners)

    def read(self, key: int, kn_name: str | None = None, _probe=None):
        """``_probe``: optional (ptr_or_None, probes) pair prefetched by
        execute_batch against the current index version -- used in place
        of the per-key index traversal on the miss path."""
        kn_name = kn_name or self.route(key)
        kn = self.kns[kn_name]
        if not kn.available or not kn.alive:
            kn.stats.refused += 1
            return None, 0.0, False
        if self.variant.name == "clover":
            return self._clover_read(kn, key)
        kn.stats.ops += 1
        kn.stats.reads += 1
        replicated = (self.variant.selective_replication
                      and self.ownership.is_replicated(key))
        rts = 0.0
        value = None
        hit = kn.cache.lookup(key)
        if hit is not None:
            kind, ptr, _len = hit
            if kind == "value" and not replicated:
                value = self.pool.read_value(ptr)[0]      # 0 RTs
            elif replicated:
                # shortcut names the indirection slot: 1 RT to read the
                # indirect pointer + 1 RT to read the value
                tgt = self.pool.read_indirect(key)
                rts += 2.0
                value = self.pool.read_value(tgt)[0] if tgt is not None \
                    else None
            else:
                rts += 1.0                                 # one-sided read
                value = self.pool.read_value(ptr)[0]
        else:
            seg = kn.segcache.get(key)
            if seg is not None and not replicated:
                ptr, length = seg
                value = self.pool.read_value(ptr)[0]       # local segment
                kn.cache.fill_after_write(key, ptr, length,
                                          segment_cached=True)
            else:
                ptr, probes = (self.pool.index_lookup(key)
                               if _probe is None else _probe)
                rts += probes                               # index traversal
                if ptr is None:
                    kn.stats.rts += rts
                    return None, rts, True
                rts += 1.0                                  # value fetch
                value, length = self.pool.read_value(ptr)
                kn.cache.note_miss_rts(rts)
                kn.cache.fill_after_miss(key, ptr, length)
        kn.stats.rts += rts
        return value, rts, True

    def write(self, key: int, value, kn_name: str | None = None,
              delete: bool = False):
        kn_name = kn_name or self.route(key)
        kn = self.kns[kn_name]
        if not kn.available or not kn.alive:
            kn.stats.refused += 1
            return 0.0, False
        if self.variant.name == "clover":
            return self._clover_write(kn, key, value, delete)
        kn.stats.ops += 1
        kn.stats.writes += 1
        self._seq += 1
        rts = kn.flush_rts()       # amortized one-sided batched log write
        length = 0 if delete else self.value_bytes
        logical_key = -key - 1 if delete else key
        replicated = (self.variant.selective_replication
                      and self.ownership.is_replicated(key) and not delete)
        ptr, rotated = self.pool.log_write(kn.name, logical_key,
                                           None if delete else value, length)
        if self.pool.write_blocked(kn.name):
            kn.stats.write_stalls += 1
            self.pool.merge_budget(self.pool.segment_capacity)
        if replicated:
            # atomically swing the indirect pointer: one-sided CAS
            expect = self.pool.read_indirect(key)
            self.pool.cas_indirect(key, expect, ptr)
            rts += 1.0
            kn.cache.update_pointer(key, ptr, length)
        elif delete:
            kn.cache.invalidate(key)
            kn.segcache.pop(key, None)
        else:
            kn._segcache_put(key, ptr, length)
            kn.cache.fill_after_write(key, ptr, length, segment_cached=True)
        self.versions[key] = self.versions.get(key, 0) + 1
        kn.stats.rts += rts
        return rts, True

    # ----- Clover request paths (shared everything, version chains) -------
    def _clover_read(self, kn: KVSNode, key: int):
        kn.stats.ops += 1
        kn.stats.reads += 1
        cur = self.versions.get(key, 0)
        cached = kn.cache.lookup(key)
        rts = 0.0
        if cached is None:
            self.ms_ops += 1            # two-sided RPC to metadata server
            rts += 1.0                  # (modeled as 1 RT-equivalent + MS load)
        ptr, _probes = self.pool.index_lookup(key)
        if ptr is None:
            kn.stats.rts += rts
            return None, rts, True
        stale = 0 if cached is None else max(cur - cached, 0)
        # walk the version chain from the cached cursor: header + value
        rts += 2.0 + stale
        kn.cache.fill(key, cur)
        value, _ = self.pool.read_value(ptr)
        kn.stats.rts += rts
        return value, rts, True

    def _clover_write(self, kn: KVSNode, key: int, value, delete: bool):
        kn.stats.ops += 1
        kn.stats.writes += 1
        length = 0 if delete else self.value_bytes
        logical_key = -key - 1 if delete else key
        ptr, _ = self.pool.log_write(kn.name, logical_key,
                                     None if delete else value, length)
        self.pool.merge_all(kn.name)    # Clover updates metadata in place
        rts = 2.0                       # out-of-place append + link/CAS
        self.versions[key] = self.versions.get(key, 0) + 1
        kn.cache.fill(key, self.versions[key])
        kn.stats.rts += rts
        return rts, True

    # ---------------------------------------------------------------------
    # batched data plane (the tentpole of the vectorized op engine):
    # routes a whole batch with one ring gather, classifies each op
    # against its owner's ArrayDAC with one gather per KN, applies runs
    # of value hits with one scatter per KN, and only drops to the exact
    # scalar path for structural ops (writes, misses, shortcut hits,
    # replicated keys). Produces *identical* statistics and cache
    # decisions to calling read()/write() per op (property-tested).
    # ---------------------------------------------------------------------
    def execute_batch(self, kinds, keys, *, value=None, values=None,
                      blocked_kns=(), collect_values: bool = False
                      ) -> "BatchResult":
        """Execute a batch of operations in submission order.

        kinds: (N,) array, 0 == read, nonzero == write
        keys:  (N,) int array
        value/values: write payloads (constant, sequence, or callable)
        blocked_kns: KN names whose ops are dropped before execution
            (the timed simulation's outage windows)
        collect_values: materialize read results (costs a python pass)
        """
        keys = np.ascontiguousarray(np.asarray(keys, dtype=np.int64))
        kinds = np.asarray(kinds, dtype=np.uint8)
        n = keys.shape[0]
        out_values: list | None = [None] * n if collect_values else None
        if n == 0 or not self.kns:
            return BatchResult(0, 0, {}, keys[:0], out_values)
        if self.variant.architecture == "shared_everything" or not all(
                isinstance(k.cache, ArrayDAC) for k in self.kns.values()):
            # clover routes through the client rng and the static caches
            # have no vectorized plane: run the fused scalar loop (same
            # per-op semantics, without the simulator-level overhead)
            return self._execute_batch_fused(kinds, keys, value, values,
                                             blocked_kns, out_values)

        names = list(self.kns.keys())
        name_idx = {nm: j for j, nm in enumerate(names)}

        # ----- vectorized routing over the ownership ring ------------------
        ring_ids, ring_names = self.ownership.primary_ids(keys)
        ring_to_kn = np.array([name_idx.get(nm, -1) for nm in ring_names],
                              dtype=np.int64)
        kn_ids = ring_to_kn[ring_ids]
        rep_arr = self.ownership.replicated_keys_array()
        if rep_arr.size:
            rep_mask = np.isin(keys, rep_arr)
            for p in np.nonzero(rep_mask)[0]:
                try:   # replicated keys draw a random owner, as scalar
                    kn_ids[p] = name_idx[self.route(int(keys[p]))]
                except KeyError:
                    kn_ids[p] = -1
        else:
            rep_mask = np.zeros(n, bool)

        # ----- availability masks ------------------------------------------
        blocked = np.zeros(len(names), bool)
        for nm in blocked_kns:
            j = name_idx.get(nm)
            if j is not None:
                blocked[j] = True
        refusing = np.array([not (self.kns[nm].alive
                                  and self.kns[nm].available)
                             for nm in names], bool)
        safe_ids = np.maximum(kn_ids, 0)
        exec_mask = (kn_ids >= 0) & ~blocked[safe_ids]
        refused_mask = exec_mask & refusing[safe_ids]
        live = exec_mask & ~refused_mask
        rcnt = np.bincount(kn_ids[refused_mask], minlength=len(names))
        for j in np.nonzero(rcnt)[0]:
            self.kns[names[j]].stats.refused += int(rcnt[j])

        # ----- prefetch index probes for the predicted misses ---------------
        # (one vectorized CLHT gather replaces per-key chain walks; each
        # use re-checks the metadata version so mid-batch merges fall
        # back to the live per-key traversal)
        probe_map: dict[int, tuple] = {}
        probe_ver = -1
        reads_m = live & (kinds == 0) & ~rep_mask
        all_reads = bool(reads_m[live].all()) if live.any() else False
        value_run_kns = []       # (kn, grp, kcls): vectorized hit runs
        for grp in self._kn_groups(np.nonzero(live)[0], kn_ids):
            cache = self.kns[names[int(kn_ids[grp[0]])]].cache
            # grow the per-key vectors up front: the fused loop caches
            # bound accessors, so the arrays must not move mid-batch
            cache._ensure(int(keys[grp].max()))
            rsub = grp[reads_m[grp]]
            if not rsub.size:
                continue
            kcls = cache.kind[keys[rsub]]
            pm_pos = rsub[kcls == ArrayDAC.KIND_NONE]
            if pm_pos.size:
                pptr, pprob = self.pool.index_lookup_batch(keys[pm_pos])
                for p, pp_, pb in zip(pm_pos.tolist(), pptr.tolist(),
                                      pprob.tolist()):
                    probe_map[p] = (None if pp_ < 0 else pp_, pb)
                probe_ver = self.pool.meta_version
            # a read-only batch whose predicted non-value-hit fraction
            # is tiny (high-skew warm caches): apply long vectorized
            # value-hit runs instead of the per-op interpreter. Safe:
            # reads of one KN only interact through that KN's cache,
            # and each run is re-validated against the live entry kinds
            # before being applied.
            if all_reads and rsub.size == grp.size and \
                    rsub.size >= 256 and \
                    int((kcls != ArrayDAC.KIND_VALUE).sum()) \
                    <= rsub.size // 20:
                value_run_kns.append((names[int(kn_ids[grp[0]])], grp,
                                      kcls))
                live[grp] = False

        for nm, grp, kcls in value_run_kns:
            self._apply_value_runs(self.kns[nm], grp, kcls, keys,
                                   probe_map, probe_ver, out_values)

        # ----- fused interpreter over the live ops, in global order ---------
        writes = self._run_fused_ops(np.nonzero(live)[0], keys, kinds,
                                     kn_ids, rep_mask, names, value,
                                     values, probe_map, probe_ver,
                                     out_values)

        cnt = np.bincount(kn_ids[exec_mask], minlength=len(names))
        per_kn = {names[j]: int(cnt[j]) for j in np.nonzero(cnt)[0]}
        # scalar loops count refused writes too (the write() call refuses
        # after the attempt is recorded by the driver)
        writes += int((kinds[refused_mask] != 0).sum())
        return BatchResult(int(exec_mask.sum()), writes, per_kn,
                           keys[exec_mask], out_values)

    def _run_fused_ops(self, live_pos, keys, kinds, kn_ids, rep_mask,
                       names, value, values, probe_map, probe_ver,
                       out_values) -> int:
        """One pass over the batch in submission order, with every op
        inlined against its owner KN's array-backed cache.

        Value hits are three list writes; always-promoting shortcut
        hits (Eq. 1 with free space or free victims -- the common case
        on warm zipfian caches) run an inlined promote-and-demote
        transition over the same lazy heaps; undecided promotions,
        misses, writes and replicated keys drop to the exact library
        methods, with the per-KN state mirrors synced around the call.
        Misses consume the batched index-probe prefetch (re-validated
        against the pool's metadata version). Per-KN statistics
        accumulate in context slots and are applied once at the end.
        The result is operation-for-operation identical to calling
        read()/write() per op (property-tested), minus the per-op
        routing and dispatch overhead.

        ctx slots: 0 kn, 1 cache, 2 count, 3 stamp, 4 kind.item,
        5 ptr, 6 clock, 7 value_hits, 8 misses, 9 rts, 10 unused,
        11 unused, 12 writes, 13 stalls, 14 length, 15 kind array,
        16 used, 17 zero_shortcuts, 18 nvals, 19 nshort,
        20 shortcut_hits, 21 promotions, 22 demotions, 23 evictions,
        24 lru heap, 25 lfu heap, 26 capacity, 27 pending mutation
        bumps (flushed to cache.mutations by sync)
        """
        pool = self.pool
        heap = pool.heap_val
        heap_len = pool.heap_len
        versions = self.versions
        vbytes = self.value_bytes
        collect = out_values is not None
        heappush, heappop = heapq.heappush, heapq.heappop
        ctxs = []
        for nm in names:
            kn = self.kns[nm]
            c = kn.cache
            ctxs.append([kn, c, c.count, c.stamp, c.kind.item, c.ptr,
                         c._clock, 0, 0, 0.0, 0, 0, 0, 0,
                         c.length, c.kind, c.used, c._zero_shortcuts,
                         c._nvals, c._nshort, 0, 0, 0, 0,
                         c._lru, c._lfu, c.capacity, 0])

        def sync(ctx):
            c = ctx[1]
            c._clock = ctx[6]
            c.used = ctx[16]
            c._zero_shortcuts = ctx[17]
            c._nvals = ctx[18]
            c._nshort = ctx[19]
            if ctx[27]:
                c.mutations += ctx[27]
                ctx[27] = 0

        def reload(ctx):
            c = ctx[1]
            ctx[6] = c._clock
            ctx[16] = c.used
            ctx[17] = c._zero_shortcuts
            ctx[18] = c._nvals
            ctx[19] = c._nshort
            ctx[24] = c._lru
            ctx[25] = c._lfu

        # the inline transitions must keep cache.mutations observable
        # (the Eq. 1 victim-sum cache keys on it), so promotions /
        # demotions / evictions bump it inside the loop via ctx[1]
        pos_l = live_pos.tolist()
        key_l = keys[live_pos].tolist()
        op_l = kinds[live_pos].tolist()
        kn_l = kn_ids[live_pos].tolist()
        if rep_mask.any():
            rep_l = rep_mask[live_pos].tolist()
        else:
            rep_l = itertools.repeat(False)
        writes = 0
        seq = 0
        for p_, k, op, j, rep in zip(pos_l, key_l, op_l, kn_l, rep_l):
            ctx = ctxs[j]
            if rep:
                # replicated keys: exact generic path (indirection RTs,
                # CAS publication)
                kn = ctx[0]
                sync(ctx)
                if op == 0:
                    r = self.read(k, kn.name)
                    if collect:
                        out_values[p_] = r[0]
                else:
                    writes += 1
                    self.write(k, self._value_at(p_, value, values),
                               kn.name)
                reload(ctx)
                continue
            if op == 0:
                kd = ctx[4](k)
                if kd == 2:                                  # value hit
                    ctx[2][k] += 1
                    ctx[3][k] = ctx[6]
                    ctx[6] += 1
                    ctx[7] += 1                              # value_hits
                    if collect:
                        out_values[p_] = heap[ctx[5][k]]
                elif kd == 1:                                # shortcut hit
                    cnt = ctx[2]
                    c = cnt[k] + 1
                    cnt[k] = c
                    if c == 1:
                        ctx[17] -= 1
                    ctx[20] += 1                             # shortcut_hits
                    ctx[9] += 1.0          # one-sided pointer chase
                    if collect:
                        out_values[p_] = heap[ctx[5][k]]
                    # Eq. 1 fast decision (exact: sufficient conditions)
                    lenl = ctx[14]
                    vb = lenl[k] + 40      # VALUE_OVERHEAD_BYTES
                    used = ctx[16]
                    free = ctx[26] - used
                    if free >= vb - 32:
                        promote = True
                    elif ctx[17] >= -((free - vb + 32) // 32):
                        promote = True     # victims all free: Eq.1 rhs 0
                    else:
                        promote = None     # undecided: exact slow path
                    if promote is None:
                        cache = ctx[1]
                        sync(ctx)
                        if cache._should_promote(k, c, lenl[k]):
                            cache._promote(k)
                            cache.stats.promotions += 1
                        reload(ctx)
                        continue
                    # ---- inline promote: shortcut -> value (Table 3) --
                    ctx[21] += 1                             # promotions
                    ctx[27] += 1                             # a mutation
                    kind_a = ctx[15]
                    kind_a[k] = 0
                    used -= 32
                    ctx[19] -= 1                             # nshort
                    cap = ctx[26]
                    stp = ctx[3]
                    # make space: demote LRU values, then evict LFU
                    if used + vb > cap:
                        lru = ctx[24]
                        nvals = ctx[18]
                        while used + vb > cap and nvals:
                            if len(lru) > 4 * nvals + 64:
                                cache = ctx[1]
                                cache._compact_lru()
                                lru = cache._lru
                                ctx[24] = lru
                            v = None
                            while lru:
                                st_, kk = heappop(lru)
                                if kind_a[kk] != 2:
                                    continue           # stale: drop
                                cur = stp[kk]
                                if cur != st_:
                                    heappush(lru, (cur, kk))  # refresh
                                    continue
                                v = kk
                                break
                            if v is None:
                                break
                            used -= lenl[v] + 40
                            nvals -= 1
                            kind_a[v] = 0
                            ctx[22] += 1                     # demotions
                            if used + 32 + vb <= cap:
                                kind_a[v] = 1
                                heappush(ctx[25], (cnt[v], v))
                                used += 32
                                ctx[19] += 1
                                if cnt[v] == 0:
                                    ctx[17] += 1
                        ctx[18] = nvals
                        while used + vb > cap and ctx[19]:
                            lfu = ctx[25]
                            if len(lfu) > 4 * ctx[19] + 64:
                                cache = ctx[1]
                                cache._compact_lfu()
                                lfu = cache._lfu
                                ctx[25] = lfu
                            v = None
                            while lfu:
                                ct_, kk = heappop(lfu)
                                if kind_a[kk] != 1:
                                    continue
                                cur = cnt[kk]
                                if cur != ct_:
                                    heappush(lfu, (cur, kk))
                                    continue
                                v = kk
                                break
                            if v is None:
                                break
                            kind_a[v] = 0
                            used -= 32
                            ctx[19] -= 1
                            if cnt[v] == 0:
                                ctx[17] -= 1
                            ctx[23] += 1                     # evictions
                    if used + vb > cap:
                        # degenerate: cannot fit the value even after
                        # demotions/evictions -> falls back to a
                        # shortcut entry, exactly as _insert_value
                        if used + 32 <= cap:
                            kind_a[k] = 1
                            heappush(ctx[25], (c, k))
                            used += 32
                            ctx[19] += 1
                    else:
                        kind_a[k] = 2
                        clock = ctx[6]
                        stp[k] = clock
                        heappush(ctx[24], (clock, k))
                        ctx[6] = clock + 1
                        used += vb
                        ctx[18] += 1
                    ctx[16] = used
                else:                                        # miss
                    ctx[8] += 1                              # misses
                    kn = ctx[0]
                    cache = ctx[1]
                    seg = kn.segcache.get(k)
                    if seg is not None:
                        ptr, length = seg    # local segment: 0 RTs
                        sync(ctx)
                        cache.fill_after_write(k, ptr, length,
                                               segment_cached=True)
                        reload(ctx)
                        if collect:
                            out_values[p_] = heap[ptr]
                    else:
                        probe = None
                        if probe_ver == pool.meta_version:
                            probe = probe_map.get(p_)
                        ptr, probes = (pool.index_lookup(k)
                                       if probe is None else probe)
                        if ptr is None:
                            ctx[9] += probes
                        else:
                            rts_op = probes + 1.0   # traversal + value
                            ctx[9] += rts_op
                            cache.note_miss_rts(rts_op)
                            sync(ctx)
                            cache.fill_after_miss(k, ptr, heap_len[ptr])
                            reload(ctx)
                            if collect:
                                out_values[p_] = heap[ptr]
            else:                                            # write
                writes += 1
                seq += 1
                ctx[12] += 1                                 # writes
                kn = ctx[0]
                pf = kn._pending_flush + 1   # amortized batched log write
                if pf >= kn.write_batch:
                    kn._pending_flush = 0
                    ctx[9] += 1.0
                else:
                    kn._pending_flush = pf
                nm = kn.name
                ptr, _rot = pool.log_write(
                    nm, k, self._value_at(p_, value, values), vbytes)
                if pool.write_blocked(nm):
                    ctx[13] += 1                             # write_stalls
                    pool.merge_budget(pool.segment_capacity)
                kn._segcache_put(k, ptr, vbytes)
                cache = ctx[1]
                sync(ctx)
                cache.fill_after_write(k, ptr, vbytes, segment_cached=True)
                reload(ctx)
                versions[k] = versions.get(k, 0) + 1
        self._seq += seq
        for ctx in ctxs:
            kn, cache = ctx[0], ctx[1]
            sync(ctx)
            cs = cache.stats
            cs.value_hits += ctx[7]
            cs.misses += ctx[8]
            cs.shortcut_hits += ctx[20]
            cs.promotions += ctx[21]
            cs.demotions += ctx[22]
            cs.evictions += ctx[23]
            kn.stats.rts += ctx[9]
            reads = ctx[7] + ctx[20] + ctx[8]
            kn.stats.ops += reads + ctx[12]
            kn.stats.reads += reads
            kn.stats.writes += ctx[12]
            kn.stats.write_stalls += ctx[13]
        return writes

    def _apply_value_runs(self, kn, grp, kcls, keys, probe_map,
                          probe_ver, out_values) -> None:
        """One KN's read-only ops, almost all predicted value hits:
        bulk-apply the hit runs between the (few) predicted structural
        reads, which take the exact generic path."""
        cur = 0
        for sl in np.nonzero(kcls != ArrayDAC.KIND_VALUE)[0].tolist():
            if sl > cur:
                self._bulk_value_run(kn, grp[cur:sl], keys, out_values)
            p = int(grp[sl])
            probe = None
            if probe_ver == self.pool.meta_version:
                probe = probe_map.get(p)
            r = self.read(int(keys[p]), kn.name, _probe=probe)
            if out_values is not None:
                out_values[p] = r[0]
            cur = sl + 1
        if cur < grp.shape[0]:
            self._bulk_value_run(kn, grp[cur:], keys, out_values)

    def _bulk_value_run(self, kn, pos, keys, out_values) -> None:
        """Apply a run of predicted value hits, re-validating against
        the live cache (an earlier structural read may have demoted or
        evicted a key); mispredictions take the exact scalar path in
        order."""
        cache = kn.cache
        while pos.size:
            ck = keys[pos]
            ok = cache.kind[ck] == ArrayDAC.KIND_VALUE
            if ok.all():
                b = pos.size
            else:
                b = int(np.argmax(~ok))
            if b:
                cache.bulk_value_hits(ck[:b])
                kn.stats.ops += b
                kn.stats.reads += b
                if out_values is not None:
                    ptr_l = cache.ptr
                    heap = self.pool.heap_val
                    for p, k in zip(pos[:b].tolist(), ck[:b].tolist()):
                        out_values[p] = heap[ptr_l[k]]
            if b == pos.size:
                return
            p = int(pos[b])
            r = self.read(int(keys[p]), kn.name)
            if out_values is not None:
                out_values[p] = r[0]
            pos = pos[b + 1:]

    @staticmethod
    def _kn_groups(pos: np.ndarray, kn_ids: np.ndarray):
        """Split sorted global positions into per-KN groups (each group
        keeps ascending op order)."""
        if not pos.size:
            return
        ids = kn_ids[pos]
        order = np.argsort(ids, kind="stable")
        sp = pos[order]
        bounds = np.nonzero(np.diff(ids[order]))[0] + 1
        yield from np.split(sp, bounds)

    def _execute_batch_fused(self, kinds, keys, value, values, blocked_kns,
                             out_values):
        blocked = set(blocked_kns)
        per_kn: dict[str, int] = {}
        writes = 0
        exec_idx = []
        read, write, route = self.read, self.write, self.route
        for i in range(keys.shape[0]):
            key = int(keys[i])
            try:
                kn = route(key)
            except KeyError:
                continue
            if kn in blocked:
                continue
            exec_idx.append(i)
            per_kn[kn] = per_kn.get(kn, 0) + 1
            if kinds[i] == 0:
                r = read(key, kn)
                if out_values is not None:
                    out_values[i] = r[0]
            else:
                writes += 1
                write(key, self._value_at(i, value, values), kn)
        idx = np.asarray(exec_idx, dtype=np.int64)
        return BatchResult(len(exec_idx), writes, per_kn, keys[idx],
                           out_values)

    @staticmethod
    def _value_at(i: int, value, values):
        if values is None:
            return value
        if callable(values):
            return values(i)
        return values[i]

    def batch_read(self, keys, collect_values: bool = True):
        """Batched read entry point: returns (values, result)."""
        keys = np.asarray(keys, dtype=np.int64)
        res = self.execute_batch(np.zeros(keys.shape[0], np.uint8), keys,
                                 collect_values=collect_values)
        return res.values, res

    def batch_write(self, keys, values):
        """Batched write entry point: returns the BatchResult."""
        keys = np.asarray(keys, dtype=np.int64)
        return self.execute_batch(np.ones(keys.shape[0], np.uint8), keys,
                                  values=values)

    # ---------------------------------------------------------------------
    # background work + bookkeeping
    # ---------------------------------------------------------------------
    def advance_merge(self, ops: int) -> int:
        return self.pool.merge_budget(ops)

    def load(self, items, warm: bool = False) -> None:
        """Bulk-load the dataset (untimed, as in the paper's load phase).
        ``warm=True`` reproduces the load-through-KN effect: under OP the
        owner inserted every key it owns, so it holds a shortcut for
        free; under shared-everything each key was handled by one
        arbitrary KN."""
        items = list(items)
        self.pool.bulk_load((k, v, self.value_bytes) for k, v in items)
        if not warm:
            return
        keys = [k for k, _ in items]
        names = list(self.kns)
        for k in keys:
            ptr, _ = self.pool.index_lookup(k)
            if ptr is None:
                continue
            if self.variant.name == "clover":
                kn = self.kns[names[stable_hash(("load", k)) % len(names)]]
                kn.cache.fill(k, self.versions.get(k, 0))
            else:
                owner = self.ownership.primary(k)
                self.kns[owner].cache.fill_after_write(
                    k, ptr, self.value_bytes, segment_cached=False)

    def aggregate_stats(self) -> dict:
        tot_ops = sum(k.stats.ops for k in self.kns.values())
        tot_rts = sum(k.stats.rts for k in self.kns.values())
        caches = [k.cache.stats for k in self.kns.values()
                  if hasattr(k.cache, "stats")]
        lookups = sum(c.lookups for c in caches)
        hits = sum(c.value_hits + c.shortcut_hits for c in caches)
        vhits = sum(c.value_hits for c in caches)
        return {
            "ops": tot_ops,
            "rts_per_op": tot_rts / tot_ops if tot_ops else 0.0,
            "hit_ratio": hits / lookups if lookups else 0.0,
            "value_hit_ratio": vhits / lookups if lookups else 0.0,
            "write_stalls": sum(k.stats.write_stalls
                                for k in self.kns.values()),
            "num_kns": len(self.kns),
        }

    def reset_stats(self) -> None:
        for kn in self.kns.values():
            kn.stats = KNStats()
            if hasattr(kn.cache, "stats"):
                kn.cache.stats = CacheStats()
        self.ms_ops = 0
