"""The DINOMO cluster: clients -> RNs -> KNs -> DPM pool (paper Fig. 1).

This is the functional simulator: every request actually runs against
the real data structures (DAC caches, CLHT index, log segments,
indirection table), and the exact number of network round trips is
accounted per operation -- the paper's primary cost metric (Tables 5/6).
Wall-clock figures are derived from RT counts via core.netmodel.

Four system variants share this machinery (paper Sec. 5):
  dinomo    OP + DAC + selective replication          (the paper's system)
  dinomo-s  OP + shortcut-only cache                  (isolates DAC's benefit)
  dinomo-n  shared-nothing + DAC                      (AsymNVM stand-in)
  clover    shared-everything + shortcut-only cache   (state of the art)
"""

from __future__ import annotations

import random
from collections import OrderedDict
from dataclasses import dataclass, field

from .dac import DAC, StaticCache, CacheStats
from .dpm_pool import DPMPool
from .mnode import PolicyConfig, PolicyEngine
from .netmodel import NetModel, DEFAULT_MODEL
from .hashring import stable_hash
from .ownership import OwnershipMap, ReconfigEvent


@dataclass(frozen=True)
class VariantConfig:
    name: str
    cache_policy: str          # "dac" | "shortcut" | "value" | "static:<f>" | "clover"
    architecture: str          # "op" | "shared_nothing" | "shared_everything"
    selective_replication: bool


DINOMO = VariantConfig("dinomo", "dac", "op", True)
DINOMO_S = VariantConfig("dinomo-s", "shortcut", "op", True)
DINOMO_N = VariantConfig("dinomo-n", "dac", "shared_nothing", False)
CLOVER = VariantConfig("clover", "clover", "shared_everything", False)
VARIANTS = {v.name: v for v in (DINOMO, DINOMO_S, DINOMO_N, CLOVER)}


def make_cache(policy: str, capacity_bytes: int):
    if policy == "dac":
        return DAC(capacity_bytes)
    if policy == "shortcut":
        return StaticCache(capacity_bytes, 0.0)
    if policy == "value":
        return StaticCache(capacity_bytes, 1.0)
    if policy.startswith("static:"):
        return StaticCache(capacity_bytes, float(policy.split(":")[1]))
    if policy == "clover":
        return CloverCache(capacity_bytes)
    raise ValueError(f"unknown cache policy {policy!r}")


class CloverCache:
    """Clover KNs keep a shortcut-only cache whose entries can go stale:
    out-of-place updates grow a version chain that readers must walk."""

    def __init__(self, capacity_bytes: int, entry_bytes: int = 32):
        self.cap_entries = max(capacity_bytes // entry_bytes, 1)
        self.entries: OrderedDict[int, int] = OrderedDict()  # key -> version
        self.stats = CacheStats()

    def lookup(self, key: int):
        v = self.entries.get(key)
        if v is None:
            self.stats.misses += 1
            return None
        self.entries.move_to_end(key)
        self.stats.shortcut_hits += 1
        return v

    def fill(self, key: int, version: int):
        self.entries[key] = version
        self.entries.move_to_end(key)
        while len(self.entries) > self.cap_entries:
            self.entries.popitem(last=False)
            self.stats.evictions += 1

    def clear(self):
        self.entries.clear()


@dataclass
class KNStats:
    ops: int = 0
    rts: float = 0.0
    reads: int = 0
    writes: int = 0
    write_stalls: int = 0
    refused: int = 0

    def reset_window(self):
        self.ops = 0
        self.rts = 0.0
        self.reads = 0
        self.writes = 0


class KVSNode:
    """One KN: cache + exclusive log + soft ownership state."""

    def __init__(self, name: str, variant: VariantConfig, cache_bytes: int,
                 pool: DPMPool, write_batch: int = 8,
                 segcache_segments: int = 4):
        self.name = name
        self.variant = variant
        self.cache = make_cache(variant.cache_policy, cache_bytes)
        self.pool = pool
        self.write_batch = write_batch
        self._pending_flush = 0
        # committed/un-merged segments cached locally (paper Sec. 4):
        # keys here are readable with zero RTs at the writing KN.
        self.segcache: OrderedDict[int, tuple[int, int]] = OrderedDict()
        self.segcache_cap = segcache_segments * pool.segment_capacity
        self.stats = KNStats()
        self.alive = True
        self.available = True      # False while participating in a reconfig

    # ----- helpers ---------------------------------------------------------
    def _segcache_put(self, key: int, ptr: int, length: int):
        self.segcache[key] = (ptr, length)
        self.segcache.move_to_end(key)
        while len(self.segcache) > self.segcache_cap:
            self.segcache.popitem(last=False)

    def flush_rts(self) -> float:
        """Amortized one-sided log-write cost: one RT per batch."""
        self._pending_flush += 1
        if self._pending_flush >= self.write_batch:
            self._pending_flush = 0
            return 1.0
        return 0.0

    def clear_soft_state(self):
        self.cache.clear()
        self.segcache.clear()


class DinomoCluster:
    """End-to-end cluster with exact RT accounting."""

    def __init__(self, variant: VariantConfig = DINOMO, num_kns: int = 4,
                 cache_bytes: int = 1 << 20, value_bytes: int = 1024,
                 model: NetModel = DEFAULT_MODEL,
                 policy: PolicyConfig | None = None,
                 num_buckets: int = 1 << 18, segment_capacity: int = 2048,
                 vnodes: int = 64, seed: int = 0):
        self.variant = variant
        self.model = model
        self.value_bytes = value_bytes
        self.cache_bytes = cache_bytes
        self.pool = DPMPool(num_buckets=num_buckets,
                            segment_capacity=segment_capacity)
        self.ownership = OwnershipMap(vnodes=vnodes)
        self.kns: dict[str, KVSNode] = {}
        self.mnode = PolicyEngine(policy or PolicyConfig())
        self.rng = random.Random(seed)
        self._kn_counter = 0
        self._seq = 0
        # Clover: per-key version counters + metadata-server op count
        self.versions: dict[int, int] = {}
        self.ms_ops = 0
        self.reconfig_log: list[dict] = []
        for _ in range(num_kns):
            self.add_kn(record=False)

    # ---------------------------------------------------------------------
    # membership
    # ---------------------------------------------------------------------
    def _new_kn_name(self) -> str:
        self._kn_counter += 1
        return f"kn{self._kn_counter}"

    def add_kn(self, record: bool = True) -> tuple[str, ReconfigEvent | None]:
        name = self._new_kn_name()
        self.pool.register_kn(name)
        self.kns[name] = KVSNode(name, self.variant, self.cache_bytes,
                                 self.pool)
        ev = self.ownership.add_kn(name)
        cost = self._reconfigure(ev) if record else None
        return name, ev if record else None

    def remove_kn(self, name: str) -> ReconfigEvent:
        ev = self.ownership.remove_kn(name)
        self._reconfigure(ev)
        self.pool.drop_kn(name)
        del self.kns[name]
        return ev

    def fail_kn(self, name: str) -> ReconfigEvent:
        """Fail-stop KN failure: DRAM (cache) contents lost; its pending
        log segments survive in DPM and are merged by a peer."""
        kn = self.kns[name]
        kn.alive = False
        kn.clear_soft_state()          # DRAM lost
        ev = self.ownership.remove_kn(name, failed=True)
        self._reconfigure(ev, failed=name)
        del self.kns[name]
        return ev

    def _reconfigure(self, ev: ReconfigEvent, failed: str | None = None):
        """Paper Sec. 3.5 seven-step protocol. Returns a cost record with
        the synchronous-merge size (netmodel converts to seconds).

        Steps: (1) identify participants, (2) participants unavailable,
        (3) synchronously merge their pending logs, (4) new mapping,
        (5) participants available (others already serving; wrongly
        routed requests are refused), (6)/(7) async propagation."""
        participants = [p for p in ev.participants if p in self.kns]
        for p in participants:
            self.kns[p].available = False                 # step 2
        merged = 0
        if failed is not None:
            merged += self.pool.merge_all(failed)         # peer merges
            self.pool.drop_kn(failed)
        for p in participants:
            merged += self.pool.merge_all(p)              # step 3
        moved_fraction = 0.0
        if self.variant.architecture == "shared_nothing":
            # AsymNVM-style: physical data reorganization is required.
            moved_fraction = 1.0 / max(len(self.kns), 1)
        for p in participants:
            if self.kns[p].alive:
                self.kns[p].clear_soft_state()            # ownership moved
                self.kns[p].available = True              # step 5
        # durable policy metadata so restarted nodes can rebuild
        self.pool.policy_metadata["ownership"] = self.ownership.snapshot_blob()
        rec = {"event": ev.kind, "node": ev.node,
               "participants": sorted(ev.participants),
               "merged_entries": merged,
               "moved_fraction": moved_fraction,
               "version": ev.new_version}
        self.reconfig_log.append(rec)
        return rec

    # ---------------------------------------------------------------------
    # selective replication mechanics (policy lives in mnode)
    # ---------------------------------------------------------------------
    def replicate_key(self, key: int, factor: int) -> None:
        if not self.variant.selective_replication:
            return
        # pending log entries for this key must reach the index before
        # the indirection slot snapshots it (paper: merge-before-share)
        for owner in self.ownership.owners(key):
            if owner in self.kns:
                self.pool.merge_all(owner)
        self.pool.install_indirect(key)
        owners = self.ownership.replicate(key, factor)
        # indirect pointers forbid value caching (paper Sec. 5.3)
        for o in owners:
            if o in self.kns:
                self.kns[o].cache.demote_to_shortcut(key)

    def dereplicate_key(self, key: int) -> None:
        for o in self.ownership.owners(key):
            if o in self.kns:
                self.kns[o].cache.invalidate(key)
        self.ownership.dereplicate(key)
        self.pool.remove_indirect(key)

    # ---------------------------------------------------------------------
    # request execution. Returns RTs charged (floats: write RTs amortize).
    # ---------------------------------------------------------------------
    def route(self, key: int) -> str:
        if self.variant.architecture == "shared_everything":
            # any KN serves any key: clients spread requests uniformly
            names = [n for n, k in self.kns.items() if k.alive]
            return self.rng.choice(names)
        owners = [o for o in self.ownership.owners(key) if o in self.kns]
        if not owners:
            raise KeyError("no owner")
        return owners[0] if len(owners) == 1 else self.rng.choice(owners)

    def read(self, key: int, kn_name: str | None = None):
        kn_name = kn_name or self.route(key)
        kn = self.kns[kn_name]
        if not kn.available or not kn.alive:
            kn.stats.refused += 1
            return None, 0.0, False
        if self.variant.name == "clover":
            return self._clover_read(kn, key)
        kn.stats.ops += 1
        kn.stats.reads += 1
        replicated = (self.variant.selective_replication
                      and self.ownership.is_replicated(key))
        rts = 0.0
        value = None
        hit = kn.cache.lookup(key)
        if hit is not None:
            kind, ptr, _len = hit
            if kind == "value" and not replicated:
                value = self.pool.read_value(ptr)[0]      # 0 RTs
            elif replicated:
                # shortcut names the indirection slot: 1 RT to read the
                # indirect pointer + 1 RT to read the value
                tgt = self.pool.read_indirect(key)
                rts += 2.0
                value = self.pool.read_value(tgt)[0] if tgt is not None \
                    else None
            else:
                rts += 1.0                                 # one-sided read
                value = self.pool.read_value(ptr)[0]
        else:
            seg = kn.segcache.get(key)
            if seg is not None and not replicated:
                ptr, length = seg
                value = self.pool.read_value(ptr)[0]       # local segment
                kn.cache.fill_after_write(key, ptr, length,
                                          segment_cached=True)
            else:
                ptr, probes = self.pool.index_lookup(key)
                rts += probes                               # index traversal
                if ptr is None:
                    kn.stats.rts += rts
                    return None, rts, True
                rts += 1.0                                  # value fetch
                value, length = self.pool.read_value(ptr)
                kn.cache.note_miss_rts(rts)
                kn.cache.fill_after_miss(key, ptr, length)
        kn.stats.rts += rts
        return value, rts, True

    def write(self, key: int, value, kn_name: str | None = None,
              delete: bool = False):
        kn_name = kn_name or self.route(key)
        kn = self.kns[kn_name]
        if not kn.available or not kn.alive:
            kn.stats.refused += 1
            return 0.0, False
        if self.variant.name == "clover":
            return self._clover_write(kn, key, value, delete)
        kn.stats.ops += 1
        kn.stats.writes += 1
        self._seq += 1
        rts = kn.flush_rts()       # amortized one-sided batched log write
        length = 0 if delete else self.value_bytes
        logical_key = -key - 1 if delete else key
        replicated = (self.variant.selective_replication
                      and self.ownership.is_replicated(key) and not delete)
        ptr, rotated = self.pool.log_write(kn.name, logical_key,
                                           None if delete else value, length)
        if self.pool.write_blocked(kn.name):
            kn.stats.write_stalls += 1
            self.pool.merge_budget(self.pool.segment_capacity)
        if replicated:
            # atomically swing the indirect pointer: one-sided CAS
            expect = self.pool.read_indirect(key)
            self.pool.cas_indirect(key, expect, ptr)
            rts += 1.0
            kn.cache.update_pointer(key, ptr, length)
        elif delete:
            kn.cache.invalidate(key)
            kn.segcache.pop(key, None)
        else:
            kn._segcache_put(key, ptr, length)
            kn.cache.fill_after_write(key, ptr, length, segment_cached=True)
        self.versions[key] = self.versions.get(key, 0) + 1
        kn.stats.rts += rts
        return rts, True

    # ----- Clover request paths (shared everything, version chains) -------
    def _clover_read(self, kn: KVSNode, key: int):
        kn.stats.ops += 1
        kn.stats.reads += 1
        cur = self.versions.get(key, 0)
        cached = kn.cache.lookup(key)
        rts = 0.0
        if cached is None:
            self.ms_ops += 1            # two-sided RPC to metadata server
            rts += 1.0                  # (modeled as 1 RT-equivalent + MS load)
        ptr, _probes = self.pool.index_lookup(key)
        if ptr is None:
            kn.stats.rts += rts
            return None, rts, True
        stale = 0 if cached is None else max(cur - cached, 0)
        # walk the version chain from the cached cursor: header + value
        rts += 2.0 + stale
        kn.cache.fill(key, cur)
        value, _ = self.pool.read_value(ptr)
        kn.stats.rts += rts
        return value, rts, True

    def _clover_write(self, kn: KVSNode, key: int, value, delete: bool):
        kn.stats.ops += 1
        kn.stats.writes += 1
        length = 0 if delete else self.value_bytes
        logical_key = -key - 1 if delete else key
        ptr, _ = self.pool.log_write(kn.name, logical_key,
                                     None if delete else value, length)
        self.pool.merge_all(kn.name)    # Clover updates metadata in place
        rts = 2.0                       # out-of-place append + link/CAS
        self.versions[key] = self.versions.get(key, 0) + 1
        kn.cache.fill(key, self.versions[key])
        kn.stats.rts += rts
        return rts, True

    # ---------------------------------------------------------------------
    # background work + bookkeeping
    # ---------------------------------------------------------------------
    def advance_merge(self, ops: int) -> int:
        return self.pool.merge_budget(ops)

    def load(self, items, warm: bool = False) -> None:
        """Bulk-load the dataset (untimed, as in the paper's load phase).
        ``warm=True`` reproduces the load-through-KN effect: under OP the
        owner inserted every key it owns, so it holds a shortcut for
        free; under shared-everything each key was handled by one
        arbitrary KN."""
        items = list(items)
        self.pool.bulk_load((k, v, self.value_bytes) for k, v in items)
        if not warm:
            return
        keys = [k for k, _ in items]
        names = list(self.kns)
        for k in keys:
            ptr, _ = self.pool.index_lookup(k)
            if ptr is None:
                continue
            if self.variant.name == "clover":
                kn = self.kns[names[stable_hash(("load", k)) % len(names)]]
                kn.cache.fill(k, self.versions.get(k, 0))
            else:
                owner = self.ownership.primary(k)
                self.kns[owner].cache.fill_after_write(
                    k, ptr, self.value_bytes, segment_cached=False)

    def aggregate_stats(self) -> dict:
        tot_ops = sum(k.stats.ops for k in self.kns.values())
        tot_rts = sum(k.stats.rts for k in self.kns.values())
        caches = [k.cache.stats for k in self.kns.values()
                  if hasattr(k.cache, "stats")]
        lookups = sum(c.lookups for c in caches)
        hits = sum(c.value_hits + c.shortcut_hits for c in caches)
        vhits = sum(c.value_hits for c in caches)
        return {
            "ops": tot_ops,
            "rts_per_op": tot_rts / tot_ops if tot_ops else 0.0,
            "hit_ratio": hits / lookups if lookups else 0.0,
            "value_hit_ratio": vhits / lookups if lookups else 0.0,
            "write_stalls": sum(k.stats.write_stalls
                                for k in self.kns.values()),
            "num_kns": len(self.kns),
        }

    def reset_stats(self) -> None:
        for kn in self.kns.values():
            kn.stats = KNStats()
            if hasattr(kn.cache, "stats"):
                kn.cache.stats = CacheStats()
        self.ms_ops = 0
