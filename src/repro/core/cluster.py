"""The DINOMO cluster: clients -> RNs -> KNs -> DPM pool (paper Fig. 1).

This is the functional simulator: every request actually runs against
the real data structures (DAC caches, CLHT index, log segments,
indirection table), and the exact number of network round trips is
accounted per operation -- the paper's primary cost metric (Tables 5/6).
Wall-clock figures are derived from RT counts via core.netmodel.

Four system variants share this machinery (paper Sec. 5):
  dinomo    OP + DAC + selective replication          (the paper's system)
  dinomo-s  OP + shortcut-only cache                  (isolates DAC's benefit)
  dinomo-n  shared-nothing + DAC                      (AsymNVM stand-in)
  clover    shared-everything + shortcut-only cache   (state of the art)
"""

from __future__ import annotations

import heapq
import itertools
import random
from collections import OrderedDict
from dataclasses import dataclass, field

import numpy as np

from .dac import (ArrayDAC, ArrayStaticCache, DAC, StaticCache,
                  CacheStats, CNT_HIST_MAX)
from .dpm_pool import DPMPool, FencedWrite
from .faults import CRASH_POINTS, KNCrash
from . import sanitize
from .mnode import PolicyConfig, PolicyEngine
from .netmodel import NetModel, DEFAULT_MODEL
from .hashring import stable_hash
from .ownership import OwnershipMap, ReconfigEvent
from .transition import (ENGINE_WALL, PLAN_STATS, plan_clover_reads,
                         plan_dac_window, plan_static_window)
from time import perf_counter


@dataclass(frozen=True)
class VariantConfig:
    name: str
    cache_policy: str          # "dac" | "shortcut" | "value" | "static:<f>" | "clover"
    architecture: str          # "op" | "shared_nothing" | "shared_everything"
    selective_replication: bool


DINOMO = VariantConfig("dinomo", "dac", "op", True)
DINOMO_S = VariantConfig("dinomo-s", "shortcut", "op", True)
DINOMO_N = VariantConfig("dinomo-n", "dac", "shared_nothing", False)
CLOVER = VariantConfig("clover", "clover", "shared_everything", False)
VARIANTS = {v.name: v for v in (DINOMO, DINOMO_S, DINOMO_N, CLOVER)}


def make_cache(policy: str, capacity_bytes: int, reference: bool = False):
    """Build a KN cache. Every policy has two decision-for-decision
    equivalent implementations (property-tested): the array-backed one
    the batched data plane vectorizes over, and the seed's
    OrderedDict/heapq one -- ``reference=True`` selects the latter as
    the oracle for equivalence tests and bench baselines."""
    if policy == "dac":
        return DAC(capacity_bytes) if reference \
            else ArrayDAC(capacity_bytes)
    if policy == "shortcut":
        return StaticCache(capacity_bytes, 0.0) if reference \
            else ArrayStaticCache(capacity_bytes, 0.0)
    if policy == "value":
        return StaticCache(capacity_bytes, 1.0) if reference \
            else ArrayStaticCache(capacity_bytes, 1.0)
    if policy.startswith("static:"):
        frac = float(policy.split(":")[1])
        return StaticCache(capacity_bytes, frac) if reference \
            else ArrayStaticCache(capacity_bytes, frac)
    if policy == "clover":
        return CloverCache(capacity_bytes) if reference \
            else ArrayCloverCache(capacity_bytes)
    raise ValueError(f"unknown cache policy {policy!r}")


class CloverCache:
    """Clover KNs keep a shortcut-only cache whose entries can go stale:
    out-of-place updates grow a version chain that readers must walk."""

    def __init__(self, capacity_bytes: int, entry_bytes: int = 32):
        self.cap_entries = max(capacity_bytes // entry_bytes, 1)
        self.entries: OrderedDict[int, int] = OrderedDict()  # key -> version
        self.stats = CacheStats()

    def lookup(self, key: int):
        v = self.entries.get(key)
        if v is None:
            self.stats.misses += 1
            return None
        self.entries.move_to_end(key)
        self.stats.shortcut_hits += 1
        return v

    def fill(self, key: int, version: int):
        self.entries[key] = version
        self.entries.move_to_end(key)
        while len(self.entries) > self.cap_entries:
            self.entries.popitem(last=False)
            self.stats.evictions += 1

    def clear(self):
        self.entries.clear()


class ArrayCloverCache:
    """Array-backed CloverCache: the batched Clover plane's version
    cache. Same policy as ``CloverCache`` decision-for-decision
    (property-tested): presence + version + recency stamp per key, LRU
    eviction through a lazy (stamp, key) heap -- argmin stamp over
    present keys equals the OrderedDict front."""

    def __init__(self, capacity_bytes: int, entry_bytes: int = 32,
                 initial_keys: int = 1024):
        self.cap_entries = max(capacity_bytes // entry_bytes, 1)
        n = max(initial_keys, 8)
        self.present = np.zeros(n, bool)
        self.ver = np.zeros(n, np.int64)
        self.stamp = np.zeros(n, np.int64)
        self._clock = 1
        self._lru: list[tuple[int, int]] = []
        self._n = 0
        self.stats = CacheStats()

    def _ensure(self, key: int) -> None:
        n = self.present.shape[0]
        if key < n:
            return
        m = max(2 * n, key + 1)
        self.present = np.concatenate(
            [self.present, np.zeros(m - n, bool)])
        self.ver = np.concatenate([self.ver, np.zeros(m - n, np.int64)])
        self.stamp = np.concatenate([self.stamp,
                                     np.zeros(m - n, np.int64)])

    def lookup(self, key: int):
        self._ensure(key)
        if not self.present[key]:
            self.stats.misses += 1
            return None
        self.stamp[key] = self._clock
        self._clock += 1
        self.stats.shortcut_hits += 1
        return self.ver[key]

    def fill(self, key: int, version: int):
        self._ensure(key)
        if not self.present[key]:
            self.present[key] = True
            self._n += 1
        self.ver[key] = version
        self.stamp[key] = self._clock
        heapq.heappush(self._lru, (self._clock, key))
        self._clock += 1
        while self._n > self.cap_entries:
            if len(self._lru) > 4 * self._n + 64:
                ks = np.flatnonzero(self.present)
                self._lru = list(zip(self.stamp[ks].tolist(),
                                     ks.tolist()))
                heapq.heapify(self._lru)
            st, k = heapq.heappop(self._lru)
            if not self.present[k]:
                continue                          # stale record: drop
            cur = self.stamp[k]
            if cur != st:
                heapq.heappush(self._lru, (cur, k))   # refresh
                continue
            self.present[k] = False
            self._n -= 1
            self.stats.evictions += 1

    def apply_plan(self, plan) -> None:
        """Apply one planned read-batch window in bulk (see
        core.transition.plan_clover_reads): deduplicated fill scatters,
        eviction-free by construction, clock-ascending LRU records."""
        if plan.fill_keys.size:
            self.present[plan.fill_keys] = True
            self.ver[plan.fill_keys] = plan.fill_ver
        if plan.stp_keys.size:
            self.stamp[plan.stp_keys] = plan.stp_vals
        self._clock += plan.clock_delta
        if plan.lru_records:
            self._lru.extend(plan.lru_records)
        self._n = plan.n_final
        self.stats.shortcut_hits += plan.shortcut_hits
        self.stats.misses += plan.misses

    def clear(self):
        self.present[:] = False
        self._lru.clear()
        self._n = 0


@dataclass
class KNStats:
    ops: int = 0
    rts: float = 0.0
    reads: int = 0
    writes: int = 0
    write_stalls: int = 0
    refused: int = 0

    def reset_window(self):
        self.ops = 0
        self.rts = 0.0
        self.reads = 0
        self.writes = 0


@dataclass
class BatchResult:
    """What a batched execution observed (aggregates the scalar loop
    would have produced; per-op stats land in kn.stats / cache.stats)."""
    executed: int                  # ops that reached a KN (incl. refused)
    writes: int                    # write attempts among them
    per_kn: dict[str, int]         # executed ops per KN name
    executed_keys: np.ndarray      # keys of executed ops, in order
    values: list | None = None     # read results iff collect_values


class _WritePlan:
    """One batch's staged write plane (built by _build_write_plan):
    per-write pointers/flush-RTs in global write order, rotation events
    for the coordinator to replay, and per-KN write positions for the
    stall scan."""
    __slots__ = ("nw", "ptrs", "rts", "wrank", "wkeys", "rotations",
                 "wpos_by_name", "segq", "rot_done", "staged",
                 "ptrs_l", "rts_l", "wrank_l")

    def __init__(self):
        self.nw = 0
        self.ptrs = None
        self.rts = None
        self.wrank = None
        self.wkeys = None
        self.ptrs_l = None
        self.rts_l = None
        self.wrank_l = None
        self.rotations: list = []
        self.wpos_by_name: dict = {}
        self.segq: dict = {}       # kn -> [(segment, lo, hi) ranges]
        self.rot_done: dict = {}   # kn -> rotations executed so far
        self.staged: dict = {}     # kn -> (logical_keys, ptrs) lists


class _KnWindow:
    """Per-KN cursor over its live non-replicated ops in a batch."""
    __slots__ = ("kn", "cache", "pos", "idx", "is_dac", "is_static")

    def __init__(self, kn, cache, pos):
        self.kn = kn
        self.cache = cache
        self.pos = pos
        self.idx = 0
        self.is_dac = isinstance(cache, ArrayDAC)
        self.is_static = isinstance(cache, ArrayStaticCache)


class KVSNode:
    """One KN: cache + exclusive log + soft ownership state."""

    def __init__(self, name: str, variant: VariantConfig, cache_bytes: int,
                 pool: DPMPool, write_batch: int = 8,
                 segcache_segments: int = 4, reference_cache: bool = False):
        self.name = name
        self.variant = variant
        self.cache = make_cache(variant.cache_policy, cache_bytes,
                                reference=reference_cache)
        if sanitize.enabled():
            sanitize.guard_cache(self.cache, name)
        self.pool = pool
        self.write_batch = write_batch
        self._pending_flush = 0
        # committed/un-merged segments cached locally (paper Sec. 4):
        # keys here are readable with zero RTs at the writing KN.
        self.segcache: OrderedDict[int, tuple[int, int]] = OrderedDict()
        self.segcache_cap = segcache_segments * pool.segment_capacity
        self.stats = KNStats()
        self.alive = True
        self.available = True      # False while participating in a reconfig
        # the ownership epoch this KN believes it holds: captured from
        # the cluster at every reconfiguration, presented with every
        # DPM mutation.  A partitioned KN keeps its *old* token while
        # the cluster moves on -- the DPM fence then rejects it.
        self.fence_token: int | None = None

    # ----- helpers ---------------------------------------------------------
    def _segcache_put(self, key: int, ptr: int, length: int):
        self.segcache[key] = (ptr, length)
        self.segcache.move_to_end(key)
        while len(self.segcache) > self.segcache_cap:
            self.segcache.popitem(last=False)

    def flush_rts(self) -> float:
        """Amortized one-sided log-write cost: one RT per batch.  A
        dropped flush ack (FaultPlane network fault) costs one retry
        RT on top."""
        self._pending_flush += 1
        if self._pending_flush >= self.write_batch:
            self._pending_flush = 0
            fp = self.pool.faults
            if fp is not None and fp.drop_flush_rt():
                return 2.0
            return 1.0
        return 0.0

    def clear_soft_state(self):
        # reconfiguration/failure path: any peer may wipe this KN's DRAM
        with sanitize.management():
            self.cache.clear()
        self.segcache.clear()


class DinomoCluster:
    """End-to-end cluster with exact RT accounting."""

    def __init__(self, variant: VariantConfig = DINOMO, num_kns: int = 4,
                 cache_bytes: int = 1 << 20, value_bytes: int = 1024,
                 model: NetModel = DEFAULT_MODEL,
                 policy: PolicyConfig | None = None,
                 num_buckets: int = 1 << 18, segment_capacity: int = 2048,
                 vnodes: int = 64, seed: int = 0,
                 reference_cache: bool = False):
        self.variant = variant
        # reference_cache selects the unoptimized per-op DAC oracle
        # (the batched plane then runs the fused per-op fallback)
        self.reference_cache = reference_cache
        self.model = model
        self.value_bytes = value_bytes
        self.cache_bytes = cache_bytes
        self.pool = DPMPool(num_buckets=num_buckets,
                            segment_capacity=segment_capacity)
        self.ownership = OwnershipMap(vnodes=vnodes)
        self.kns: dict[str, KVSNode] = {}
        self.mnode = PolicyEngine(policy or PolicyConfig())
        self.rng = random.Random(seed)
        self._kn_counter = 0
        self._seq = 0
        # batch engine selection ("host" | "jit"), set per execute_batch
        self._engine = "host"
        self._jit = None        # lazy JitEngine (jit_engine.py)
        # Clover: per-key version counters + metadata-server op count
        self.versions: dict[int, int] = {}
        self.ms_ops = 0
        self.reconfig_log: list[dict] = []
        for _ in range(num_kns):
            self.add_kn(record=False)

    # ---------------------------------------------------------------------
    # membership
    # ---------------------------------------------------------------------
    def _new_kn_name(self) -> str:
        self._kn_counter += 1
        return f"kn{self._kn_counter}"

    def add_kn(self, record: bool = True) -> tuple[str, ReconfigEvent | None]:
        name = self._new_kn_name()
        self.pool.register_kn(name)
        self.kns[name] = KVSNode(name, self.variant, self.cache_bytes,
                                 self.pool,
                                 reference_cache=self.reference_cache)
        ev = self.ownership.add_kn(name)
        cost = self._reconfigure(ev) if record else None
        if not record:
            # initial construction bypasses _reconfigure; the fence
            # table still has to reach the pool before any write
            self._publish_fences()
        return name, ev if record else None

    def remove_kn(self, name: str) -> ReconfigEvent:
        ev = self.ownership.remove_kn(name)
        self._reconfigure(ev)
        self.pool.drop_kn(name)
        del self.kns[name]
        return ev

    def fail_kn(self, name: str) -> ReconfigEvent:
        """Fail-stop KN failure: DRAM (cache) contents lost; its pending
        log segments survive in DPM and are merged by a peer."""
        kn = self.kns[name]
        kn.alive = False
        kn.clear_soft_state()          # DRAM lost
        ev = self.ownership.remove_kn(name, failed=True)
        self._reconfigure(ev, failed=name)
        del self.kns[name]
        return ev

    def _reconfigure(self, ev: ReconfigEvent, failed: str | None = None):
        """Paper Sec. 3.5 seven-step protocol. Returns a cost record with
        the synchronous-merge size (netmodel converts to seconds).

        Steps: (1) identify participants, (2) participants unavailable,
        (3) synchronously merge their pending logs, (4) new mapping,
        (5) participants available (others already serving; wrongly
        routed requests are refused), (6)/(7) async propagation."""
        participants = [p for p in ev.participants if p in self.kns]
        for p in participants:
            self.kns[p].available = False                 # step 2
        # fence the handoff *before* anyone touches the moved ranges:
        # the ownership map already bumped the participants' (and a
        # failed node's) generations, so publishing here invalidates
        # every token the old owners still hold -- a zombie that heals
        # after this point can no longer mutate DPM state
        self._publish_fences()
        merged = 0
        recovery = None
        if failed is not None:
            # crash-consistent recovery by a peer (paper Sec. 3.6): the
            # failed KN's segments are recovered -- torn tails
            # discarded, sealed-but-unmerged entries replayed, dangling
            # indirection repaired -- not just merged; a crash can leave
            # state merge_all would mis-account (see DPMPool.recover_kn)
            recovery = self.pool.recover_kn(failed)
            merged += recovery["replayed"]
            self.pool.drop_kn(failed)
        for p in participants:
            merged += self.pool.merge_all(p)              # step 3
        moved_fraction = 0.0
        if self.variant.architecture == "shared_nothing":
            # AsymNVM-style: physical data reorganization is required.
            moved_fraction = 1.0 / max(len(self.kns), 1)
        for p in participants:
            if self.kns[p].alive:
                self.kns[p].clear_soft_state()            # ownership moved
                self.kns[p].available = True              # step 5
        # durable policy metadata so restarted nodes can rebuild
        self.pool.policy_metadata["ownership"] = self.ownership.snapshot_blob()
        rec = {"event": ev.kind, "node": ev.node,
               "participants": sorted(ev.participants),
               "merged_entries": merged,
               "moved_fraction": moved_fraction,
               "version": ev.new_version}
        if recovery is not None:
            rec["recovery"] = recovery
        self.reconfig_log.append(rec)
        return rec

    def _publish_fences(self) -> None:
        """Install the ownership map's fence generations at the pool
        (the store-side fence every DPM mutation validates against) and
        refresh the tokens live KNs hold in soft state."""
        self.pool.publish_fences(self.ownership.fence)
        for nm, kn in self.kns.items():
            if kn.alive:    # a dead/zombie node keeps its stale token
                kn.fence_token = self.ownership.fence.get(nm)

    # ---------------------------------------------------------------------
    # selective replication mechanics (policy lives in mnode)
    # ---------------------------------------------------------------------
    def replicate_key(self, key: int, factor: int) -> None:
        if not self.variant.selective_replication:
            return
        # pending log entries for this key must reach the index before
        # the indirection slot snapshots it (paper: merge-before-share)
        for owner in self.ownership.owners(key):
            if owner in self.kns:
                self.pool.merge_all(owner)
        self.pool.install_indirect(key)
        owners = self.ownership.replicate(key, factor)
        # indirect pointers forbid value caching (paper Sec. 5.3)
        with sanitize.management():
            for o in owners:
                if o in self.kns:
                    self.kns[o].cache.demote_to_shortcut(key)

    def dereplicate_key(self, key: int) -> None:
        with sanitize.management():
            for o in self.ownership.owners(key):
                if o in self.kns:
                    self.kns[o].cache.invalidate(key)
        self.ownership.dereplicate(key)
        self.pool.remove_indirect(key)

    # ---------------------------------------------------------------------
    # request execution. Returns RTs charged (floats: write RTs amortize).
    # ---------------------------------------------------------------------
    def route(self, key: int) -> str:
        if self.variant.architecture == "shared_everything":
            # any KN serves any key: clients spread requests uniformly
            names = [n for n, k in self.kns.items() if k.alive]
            return self.rng.choice(names)
        owners = [o for o in self.ownership.owners(key) if o in self.kns]
        if not owners:
            raise KeyError("no owner")
        return owners[0] if len(owners) == 1 else self.rng.choice(owners)

    def read(self, key: int, kn_name: str | None = None, _probe=None):
        """``_probe``: optional (ptr_or_None, probes) pair prefetched by
        execute_batch against the current index version -- used in place
        of the per-key index traversal on the miss path."""
        kn_name = kn_name or self.route(key)
        with sanitize.owned(kn_name):
            return self._read_at(key, kn_name, _probe)

    def _read_at(self, key: int, kn_name: str, _probe=None):
        kn = self.kns[kn_name]
        if not kn.available or not kn.alive:
            kn.stats.refused += 1
            return None, 0.0, False
        if self.variant.name == "clover":
            return self._clover_read(kn, key)
        kn.stats.ops += 1
        kn.stats.reads += 1
        replicated = (self.variant.selective_replication
                      and self.ownership.is_replicated(key))
        rts = 0.0
        value = None
        hit = kn.cache.lookup(key)
        if hit is not None:
            kind, ptr, _len = hit
            if kind == "value" and not replicated:
                value = self.pool.read_value(ptr)[0]      # 0 RTs
            elif replicated:
                # shortcut names the indirection slot: 1 RT to read the
                # indirect pointer + 1 RT to read the value
                tgt = self.pool.read_indirect(key)
                rts += 2.0
                value = self.pool.read_value(tgt)[0] if tgt is not None \
                    else None
            else:
                rts += 1.0                                 # one-sided read
                value = self.pool.read_value(ptr)[0]
        else:
            seg = kn.segcache.get(key)
            if seg is not None and not replicated:
                ptr, length = seg
                value = self.pool.read_value(ptr)[0]       # local segment
                kn.cache.fill_after_write(key, ptr, length,
                                          segment_cached=True)
            else:
                ptr, probes = (self.pool.index_lookup(key)
                               if _probe is None else _probe)
                rts += probes                               # index traversal
                if ptr is None:
                    kn.stats.rts += rts
                    return None, rts, True
                rts += 1.0                                  # value fetch
                value, length = self.pool.read_value(ptr)
                kn.cache.note_miss_rts(rts)
                kn.cache.fill_after_miss(key, ptr, length)
        kn.stats.rts += rts
        return value, rts, True

    def write(self, key: int, value, kn_name: str | None = None,
              delete: bool = False, req_id: int = -1):
        kn_name = kn_name or self.route(key)
        with sanitize.owned(kn_name):
            return self._write_at(key, value, kn_name, delete, req_id)

    def _write_at(self, key: int, value, kn_name: str,
                  delete: bool = False, req_id: int = -1):
        kn = self.kns[kn_name]
        if not kn.available or not kn.alive:
            kn.stats.refused += 1
            return 0.0, False
        if self.variant.name == "clover":
            return self._clover_write(kn, key, value, delete, req_id)
        kn.stats.ops += 1
        kn.stats.writes += 1
        self._seq += 1
        rts = kn.flush_rts()       # amortized one-sided batched log write
        length = 0 if delete else self.value_bytes
        logical_key = -key - 1 if delete else key
        replicated = (self.variant.selective_replication
                      and self.ownership.is_replicated(key) and not delete)
        res = self.pool.log_write(kn.name, logical_key,
                                  None if delete else value, length,
                                  req_id=req_id, token=kn.fence_token)
        if isinstance(res, FencedWrite):
            kn.stats.refused += 1       # stale epoch: clean no-op
            return 0.0, False
        ptr, rotated = res
        if self.pool.write_blocked(kn.name):
            kn.stats.write_stalls += 1
            self.pool.merge_budget(self.pool.segment_capacity)
        if replicated:
            # atomically swing the indirect pointer: one-sided CAS
            expect = self.pool.read_indirect(key)
            self.pool.cas_indirect(key, expect, ptr,
                                   kn=kn.name, token=kn.fence_token)
            rts += 1.0
            kn.cache.update_pointer(key, ptr, length)
        elif delete:
            kn.cache.invalidate(key)
            kn.segcache.pop(key, None)
        else:
            kn._segcache_put(key, ptr, length)
            kn.cache.fill_after_write(key, ptr, length, segment_cached=True)
        self.versions[key] = self.versions.get(key, 0) + 1
        kn.stats.rts += rts
        return rts, True

    # ----- Clover request paths (shared everything, version chains) -------
    def _clover_read(self, kn: KVSNode, key: int):
        kn.stats.ops += 1
        kn.stats.reads += 1
        cur = self.versions.get(key, 0)
        cached = kn.cache.lookup(key)
        rts = 0.0
        if cached is None:
            self.ms_ops += 1            # two-sided RPC to metadata server
            rts += 1.0                  # (modeled as 1 RT-equivalent + MS load)
        ptr, _probes = self.pool.index_lookup(key)
        if ptr is None:
            kn.stats.rts += rts
            return None, rts, True
        stale = 0 if cached is None else max(cur - cached, 0)
        # walk the version chain from the cached cursor: header + value
        rts += 2.0 + stale
        kn.cache.fill(key, cur)
        value, _ = self.pool.read_value(ptr)
        kn.stats.rts += rts
        return value, rts, True

    def _clover_write(self, kn: KVSNode, key: int, value, delete: bool,
                      req_id: int = -1):
        kn.stats.ops += 1
        kn.stats.writes += 1
        length = 0 if delete else self.value_bytes
        logical_key = -key - 1 if delete else key
        res = self.pool.log_write(kn.name, logical_key,
                                  None if delete else value, length,
                                  req_id=req_id, token=kn.fence_token)
        if isinstance(res, FencedWrite):
            kn.stats.refused += 1
            return 0.0, False
        ptr, _ = res
        self.pool.merge_all(kn.name)    # Clover updates metadata in place
        rts = 2.0                       # out-of-place append + link/CAS
        self.versions[key] = self.versions.get(key, 0) + 1
        kn.cache.fill(key, self.versions[key])
        kn.stats.rts += rts
        return rts, True

    # ---------------------------------------------------------------------
    # batched data plane (vectorized op engine, PR 1 read plane + PR 2
    # write plane): routes a whole batch with one consistent-hash
    # gather, stages the entire write plane up front (one bulk heap
    # extension, bulk per-KN segment fills, precomputed amortized-flush
    # RTs), then coordinates the batch as per-KN windows between global
    # events -- segment rotations, stall-triggered merges (which run
    # through the pool's planned merge plane: merge_entries_batch plans
    # each window as a MergeWindowPlan and applies it in bulk), and
    # replicated-key ops. Inside a window, per-KN streams are provably
    # independent, so ops are applied as vectorized runs (bulk value
    # hits, bulk write fills) with exact scalar fallbacks at every
    # boundary the vectorized regime cannot prove. Produces *identical*
    # statistics and cache decisions to calling read()/write() per op
    # (property-tested in tests/test_dataplane.py + test_writeplane.py).
    # ---------------------------------------------------------------------
    def execute_batch(self, kinds, keys, *, value=None, values=None,
                      blocked_kns=(), collect_values: bool = False,
                      req_ids=None, engine: str | None = None) \
            -> "BatchResult":
        """Execute a batch of operations in submission order.

        kinds: (N,) array, 0 == read, 1 == write, 2 == delete
        keys:  (N,) int array
        value/values: write payloads (constant, sequence, or callable)
        blocked_kns: KN names whose ops are dropped before execution
            (the timed simulation's outage windows)
        collect_values: materialize read results (costs a python pass)
        req_ids: optional (N,) int array of client request IDs (-1 for
            none); write entries carry them into the durable log so the
            open-loop request plane's retries deduplicate exactly-once
            (DPMPool.req_index)
        engine: None/"host" -> the host window engine; "jit" -> the
            compiled batch executor (core.jit_engine): eligible
            ArrayDAC windows run as single jitted dispatches over
            device-resident cache state, truncation residuals and
            everything else replay through the host engine, so the
            result is decision-for-decision identical (property-tested
            in tests/test_dataplane.py / test_writeplane.py)
        """
        if engine not in (None, "host", "jit"):
            raise ValueError(f"unknown engine {engine!r}")
        self._engine = engine or "host"
        keys = np.ascontiguousarray(np.asarray(keys, dtype=np.int64))
        kinds = np.asarray(kinds, dtype=np.uint8)
        if req_ids is not None:
            req_ids = np.asarray(req_ids, dtype=np.int64)
        n = keys.shape[0]
        out_values: list | None = [None] * n if collect_values else None
        if n == 0 or not self.kns:
            return BatchResult(0, 0, {}, keys[:0], out_values)
        if self.variant.architecture == "shared_everything":
            if all(isinstance(k.cache, ArrayCloverCache)
                   for k in self.kns.values()) \
                    and not self.pool.indirect \
                    and not self.pool.merge_backlog \
                    and all(not s[-1].entries
                            for s in self.pool.segments.values()):
                # clover merges per write, so the batched plane assumes
                # (and every batch re-establishes) empty active logs
                return self._execute_batch_clover(kinds, keys, value,
                                                  values, blocked_kns,
                                                  out_values, req_ids)
            return self._execute_batch_fused(kinds, keys, value, values,
                                             blocked_kns, out_values,
                                             req_ids)
        if not all(isinstance(k.cache, (ArrayDAC, ArrayStaticCache))
                   for k in self.kns.values()):
            # reference caches have no vectorized plane: run the fused
            # scalar loop (same per-op semantics, minus driver overhead)
            return self._execute_batch_fused(kinds, keys, value, values,
                                             blocked_kns, out_values,
                                             req_ids)
        return self._execute_batch_spans(kinds, keys, value, values,
                                         blocked_kns, out_values, req_ids)

    def _execute_batch_spans(self, kinds, keys, value, values, blocked_kns,
                             out_values, req_ids=None) -> "BatchResult":
        names = list(self.kns.keys())
        name_idx = {nm: j for j, nm in enumerate(names)}
        n = keys.shape[0]

        # ----- vectorized routing over the ownership ring ------------------
        ring_ids, ring_names = self.ownership.primary_ids(keys)
        ring_to_kn = np.array([name_idx.get(nm, -1) for nm in ring_names],
                              dtype=np.int64)
        kn_ids = ring_to_kn[ring_ids]
        rep_arr = self.ownership.replicated_keys_array()
        if rep_arr.size:
            rep_mask = np.isin(keys, rep_arr)
            for p in np.nonzero(rep_mask)[0]:
                try:   # replicated keys draw a random owner, as scalar
                    kn_ids[p] = name_idx[self.route(int(keys[p]))]
                except KeyError:
                    kn_ids[p] = -1
        else:
            rep_mask = np.zeros(n, bool)

        # ----- availability masks ------------------------------------------
        blocked = np.zeros(len(names), bool)
        for nm in blocked_kns:
            j = name_idx.get(nm)
            if j is not None:
                blocked[j] = True
        refusing = np.array([not (self.kns[nm].alive
                                  and self.kns[nm].available)
                             for nm in names], bool)
        safe_ids = np.maximum(kn_ids, 0)
        exec_mask = (kn_ids >= 0) & ~blocked[safe_ids]
        refused_mask = exec_mask & refusing[safe_ids]
        live = exec_mask & ~refused_mask
        rcnt = np.bincount(kn_ids[refused_mask], minlength=len(names))
        for j in np.nonzero(rcnt)[0]:
            self.kns[names[j]].stats.refused += int(rcnt[j])

        # ----- stage the write plane ---------------------------------------
        pool = self.pool
        plan = self._build_write_plan(kinds, keys, kn_ids, live, names,
                                      value, values, req_ids)

        # ----- per-KN windows + predicted-miss probe prefetch --------------
        # (one vectorized CLHT gather replaces per-key chain walks; each
        # prefetched probe stays exact until a mid-batch merge remaps
        # its key or grows its bucket chain -- the pool's dirty sets --
        # after which that key's misses take the live per-key traversal,
        # exactly as the per-op path would)
        probe_map: dict[int, tuple] = {}
        dkeys, dbuckets = pool.track_merge_dirty()
        windows = []
        for grp in self._kn_groups(np.nonzero(live & ~rep_mask)[0], kn_ids):
            kn = self.kns[names[int(kn_ids[grp[0]])]]
            cache = kn.cache
            # grow the per-key vectors up front: the window loops cache
            # bound accessors, so the arrays must not move mid-batch
            cache._ensure(int(keys[grp].max()))
            rsub = grp[kinds[grp] == 0]
            if rsub.size:
                pm = rsub[cache.kind[keys[rsub]] == 0]
                if pm.size:
                    pk = keys[pm]
                    pptr, pprob = pool.index_lookup_batch(pk)
                    pbuck = pool.index._bucket_batch(pk)
                    for p_, pp, pb, bk in zip(pm.tolist(), pptr.tolist(),
                                              pprob.tolist(),
                                              pbuck.tolist()):
                        probe_map[p_] = (None if pp < 0 else pp, pb, bk)
            windows.append(_KnWindow(kn, cache, grp))

        # ----- event-driven coordination -----------------------------------
        # Global events order the cross-KN interactions exactly as the
        # per-op loop would: a rotation pushes its segment to the shared
        # FIFO backlog at its global position; a blocked KN's write
        # stalls and merges one budget chunk (all KNs' windows advance
        # first, so their reads observe the pre-merge index); a
        # replicated-key op synchronizes on the shared indirection slot.
        rep_pos = np.nonzero(live & rep_mask)[0]
        rot = plan.rotations
        cap = pool.segment_capacity
        stalls: dict[str, int] = {}
        try:
            ri, nrot = 0, len(rot)
            si, nrep = 0, int(rep_pos.size)
            cursor = -1
            while True:
                nr = rot[ri][0] if ri < nrot else n
                nrp = int(rep_pos[si]) if si < nrep else n
                ns, ns_name = n, None
                for nm, arr in plan.wpos_by_name.items():
                    if arr.size and pool.write_blocked(nm):
                        ii = int(np.searchsorted(arr, cursor, side="right"))
                        if ii < arr.size and arr[ii] < ns:
                            ns, ns_name = int(arr[ii]), nm
                p = min(nr, nrp, ns)
                if p >= n:
                    break
                if nr == p:                       # segment rotation
                    pos_, nm = rot[ri]
                    ri += 1
                    self._fill_planned_segment(plan, nm, final=False)
                    cursor = max(cursor, pos_)
                    if pool.write_blocked(nm):    # the rotating write stalls
                        self._advance_windows(windows, pos_, keys, kinds,
                                              plan, probe_map, dkeys,
                                              dbuckets, out_values)
                        stalls[nm] = stalls.get(nm, 0) + 1
                        pool.merge_budget(cap)
                    continue
                if ns == p:                       # stalled write
                    self._advance_windows(windows, p, keys, kinds, plan,
                                          probe_map, dkeys, dbuckets,
                                          out_values)
                    stalls[ns_name] = stalls.get(ns_name, 0) + 1
                    pool.merge_budget(cap)
                    cursor = p
                    continue
                # replicated-key op: exact generic path at its position
                self._advance_windows(windows, p - 1, keys, kinds, plan,
                                      probe_map, dkeys, dbuckets,
                                      out_values)
                if self._jit is not None:
                    # rep ops touch caches through the scalar paths:
                    # scatter device-resident state back first
                    self._jit.sync_all()
                self._exec_rep_op(p, kinds, keys, kn_ids, names, plan,
                                  dkeys, out_values)
                si += 1
                cursor = max(cursor, p)
            self._advance_windows(windows, n - 1, keys, kinds, plan,
                                  probe_map, dkeys, dbuckets, out_values)
        finally:
            if self._jit is not None:
                self._jit.end_batch()
            pool.untrack_merge_dirty()

        # ----- finalize -----------------------------------------------------
        for nm in plan.segq:
            self._fill_planned_segment(plan, nm, final=True)
        for nm, c in stalls.items():
            self.kns[nm].stats.write_stalls += c
        nw = plan.nw
        if nw:
            vs = self.versions
            uk, uc = np.unique(plan.wkeys, return_counts=True)
            for k, c in zip(uk.tolist(), uc.tolist()):
                vs[k] = vs.get(k, 0) + c
            self._seq += nw
        cnt = np.bincount(kn_ids[exec_mask], minlength=len(names))
        per_kn = {names[j]: int(cnt[j]) for j in np.nonzero(cnt)[0]}
        # scalar loops count refused writes too (the write() call refuses
        # after the attempt is recorded by the driver)
        writes = nw + int((kinds[refused_mask] != 0).sum())
        return BatchResult(int(exec_mask.sum()), writes, per_kn,
                           keys[exec_mask], out_values)

    def _build_write_plan(self, kinds, keys, kn_ids, live, names, value,
                          values, req_ids=None) -> "_WritePlan":
        """Stage every live write's log append up front: one bulk heap
        extension in global write order (pointer values are observable,
        so allocation order must match the per-op sequence) with the
        owning segments pre-assigned, vectorized amortized-flush RTs
        from each KN's pending-flush counter, and the rotation schedule
        (purely count-based, hence exact). Segment *entries* are filled
        lazily -- a segment's entries land when it rotates (or at batch
        end for the final partial segment), which is exactly when the
        per-op path would have completed them; filling earlier would
        inflate unmerged_count and distort the write-stall cadence."""
        pool = self.pool
        plan = _WritePlan()
        wpos = np.nonzero(live & (kinds != 0))[0]
        nw = int(wpos.size)
        plan.nw = nw
        if nw == 0:
            return plan
        wkeys = keys[wpos]
        wkn = kn_ids[wpos]
        wdel = kinds[wpos] == 2
        vb = self.value_bytes
        del_l = wdel.tolist()
        vals = [None if d else self._value_at(p, value, values)
                for p, d in zip(wpos.tolist(), del_l)]
        lens = [0 if d else vb for d in del_l]
        base = pool.alloc_values_batch(vals, lens)
        ptrs = base + np.arange(nw, dtype=np.int64)
        rts = np.zeros(nw, np.float64)
        cap = pool.segment_capacity
        hs = pool.heap_seg
        rotations = []
        for j in np.unique(wkn):
            nm = names[int(j)]
            kn = self.kns[nm]
            sel = np.nonzero(wkn == j)[0]
            m = sel.size
            seq = np.arange(1, m + 1)
            flags = (kn._pending_flush + seq) % kn.write_batch == 0
            r = flags.astype(np.float64)
            fp = pool.faults
            if fp is not None and fp.drop_flush_rt_rate > 0.0:
                # dropped flush acks: one retry RT per dropped flush
                # (draw order is per-KN here vs per-op in the scalar
                # loop, so fault runs are not bit-equivalent -- rate 0
                # consumes no randomness and stays exact)
                nf = int(flags.sum())
                if nf:
                    r[flags] += fp.drop_flush_mask(nf)
            rts[sel] = r
            kn._pending_flush = (kn._pending_flush + m) % kn.write_batch
            logical = np.where(wdel[sel], -wkeys[sel] - 1, wkeys[sel])
            pl = ptrs[sel].tolist()
            rq = [-1] * m if req_ids is None \
                else req_ids[wpos[sel]].tolist()
            # segment ranges: the active segment takes the first
            # cap - c0 staged entries, fresh segments take cap each
            active = pool.segments[nm][-1]
            if len(active.entries) >= cap:
                # defensively rotate a full active segment (log_write
                # and the event loop never leave one, but an external
                # caller could) -- mirrors fill_segments_batch
                pool.merge_backlog.append((active, 0))
                active = pool.new_segment(nm)
                pool.segments[nm].append(active)
                pool.gc.segments_created += 1
            c0 = len(active.entries)
            segq: list[tuple] = []
            lo = 0
            seg = active
            while True:
                hi_ = min(lo + (cap if lo else cap - c0), m)
                segq.append((seg, lo, hi_))
                for p in pl[lo:hi_]:
                    hs[p] = seg
                lo = hi_
                if lo >= m:
                    break
                seg = pool.new_segment(nm)
            rotm = (c0 + seq) % cap == 0
            rpos = wpos[sel][rotm]
            # every full range in segq corresponds to one rotation
            assert int(rotm.sum()) == sum(
                1 for s, a, b in segq
                if b - a == (cap if a else cap - c0))
            rotations.extend(zip(rpos.tolist(), itertools.repeat(nm)))
            plan.segq[nm] = segq
            plan.rot_done[nm] = 0
            plan.staged[nm] = (logical.tolist(), pl, rq)
            plan.wpos_by_name[nm] = wpos[sel]
        rotations.sort(key=lambda t: t[0])
        plan.rotations = rotations
        plan.ptrs = ptrs
        plan.rts = rts
        plan.wkeys = wkeys
        wrank = np.full(keys.shape[0], -1, np.int64)
        wrank[wpos] = np.arange(nw)
        plan.wrank = wrank
        # list mirrors for the per-op window loops (python list indexing
        # beats numpy scalar indexing in the short-run regime)
        plan.ptrs_l = ptrs.tolist()
        plan.rts_l = rts.tolist()
        plan.wrank_l = wrank.tolist()
        return plan

    def _fill_planned_segment(self, plan, nm, final: bool) -> None:
        """Land a planned segment's staged entries. ``final=False``:
        the segment just rotated -- fill it to capacity, enqueue it for
        async merge, and install the next planned (or a fresh) segment
        as the KN's active one, exactly as per-op log_write would have.
        ``final=True``: the batch is over -- fill the partial tail."""
        pool = self.pool
        k = plan.rot_done.get(nm, 0)
        segq = plan.segq.get(nm)
        if segq is None or k >= len(segq):
            return
        seg, lo, hi = segq[k]
        g = pool._gen_of(nm, self.kns[nm].fence_token)
        fp = pool.faults
        if fp is not None and fp.armed and hi > lo:
            j = fp.take_crash(CRASH_POINTS.LOG_PRE_SEAL, nm, hi - lo)
            if j is not None:
                # j staged entries of this fill sealed; the (j+1)-th
                # landed torn (its seal byte never made it to DPM)
                lk, pl, rq = plan.staged[nm]
                seg.entries.extend(zip(lk[lo:lo + j + 1],
                                       pl[lo:lo + j + 1]))
                seg.sealed.extend([True] * j + [False])
                seg.reqs.extend(rq[lo:lo + j + 1])
                seg.gens.extend([g] * (j + 1))
                seg.valid += j + 1
                # only the sealed prefix durably applied; the torn
                # entry's request stays unregistered so its retry lands
                pool.register_reqs(rq[lo:lo + j], pl[lo:lo + j])
                raise KNCrash(nm, CRASH_POINTS.LOG_PRE_SEAL)
        if not final:
            lk, pl, rq = plan.staged[nm]
            seg.entries.extend(zip(lk[lo:hi], pl[lo:hi]))
            seg.sealed.extend([True] * (hi - lo))
            seg.reqs.extend(rq[lo:hi])
            seg.gens.extend([g] * (hi - lo))
            seg.valid += hi - lo
            pool.register_reqs(rq[lo:hi], pl[lo:hi])
            plan.rot_done[nm] = k + 1
            if fp is not None and fp.armed and \
                    fp.take_crash(CRASH_POINTS.LOG_ROTATION, nm, 1) is not None:
                # the filled segment sealed but was never published to
                # the shared merge backlog; recovery must rediscover it
                raise KNCrash(nm, CRASH_POINTS.LOG_ROTATION)
            pool.merge_backlog.append((seg, 0))
            nxt = segq[k + 1][0] if k + 1 < len(segq) \
                else pool.new_segment(nm)
            pool.segments[nm].append(nxt)
            pool.gc.segments_created += 1
            return
        # batch end: the remaining range (if any) is the partial tail
        if hi > lo:
            lk, pl, rq = plan.staged[nm]
            seg.entries.extend(zip(lk[lo:hi], pl[lo:hi]))
            seg.sealed.extend([True] * (hi - lo))
            seg.reqs.extend(rq[lo:hi])
            seg.gens.extend([g] * (hi - lo))
            seg.valid += hi - lo
            pool.register_reqs(rq[lo:hi], pl[lo:hi])
            plan.rot_done[nm] = k + 1

    # ----- window processing -----------------------------------------------
    def _advance_windows(self, windows, hi, keys, kinds, plan, probe_map,
                         dkeys, dbuckets, out_values) -> None:
        for w in windows:
            pos = w.pos
            if w.idx < pos.size and pos[w.idx] <= hi:
                self._run_window(w, hi, keys, kinds, plan, probe_map,
                                 dkeys, dbuckets, out_values)

    def _run_window(self, w, hi, keys, kinds, plan, probe_map, dkeys,
                    dbuckets, out_values) -> None:
        """One KN's ops in (last window end, hi], in order.

        Plan phase first: the whole window's transitions are planned as
        arrays (core.transition) and applied in bulk through the
        cache's apply_plan.  Windows the planner cannot prove replay
        through the exact per-op machinery below: classify the span
        with one kind-gather, split into maximal same-class runs, apply
        vectorizable runs in bulk (re-validated against the live cache
        at run boundaries), drop to the exact scalar op otherwise."""
        with sanitize.owned(w.kn.name):
            self._run_window_at(w, hi, keys, kinds, plan, probe_map,
                                dkeys, dbuckets, out_values)

    def _run_window_at(self, w, hi, keys, kinds, plan, probe_map, dkeys,
                       dbuckets, out_values) -> None:
        pos = w.pos
        i0 = w.idx
        i1 = int(np.searchsorted(pos, hi, side="right"))
        if i1 <= i0:
            return
        w.idx = i1
        full = pos[i0:i1]
        if self._engine == "jit" and w.is_dac:
            eng = self._jit
            if eng is None:
                from .jit_engine import JitEngine
                eng = self._jit = JitEngine(self)
            if eng.run_window(w, full, keys, kinds, plan, probe_map,
                              dkeys, dbuckets, out_values):
                return
            # ineligible window (int32 guards / too small): host engine
        kn, cache = w.kn, w.cache
        is_dac = w.is_dac
        planner = plan_dac_window if is_dac else \
            (plan_static_window if w.is_static else None)
        collect = out_values is not None
        start = 0
        n_all = full.size
        while start < n_all:
            span = full[start:] if start else full
            skeys = keys[span]
            sops = kinds[span]
            if planner is not None and span.size >= 48 \
                    and not sops.any():
                kdq = cache.kind[skeys]
                oddballs = int((kdq != 2).sum())
                if oddballs == 0:
                    # pure value-hit window (the high-skew read-only
                    # regime): one bulk scatter, no planning overhead
                    PLAN_STATS["planned_windows"] += 1
                    PLAN_STATS["planned_ops"] += int(span.size)
                    self._vh_run_big(kn, cache, span, skeys, probe_map,
                                     dkeys, dbuckets, out_values)
                    return
                if oddballs * 32 < span.size:
                    # hit-dominated read window: the run machinery's
                    # bulk value-hit path beats planning overhead
                    PLAN_STATS["replayed_windows"] += 1
                    PLAN_STATS["replayed_ops"] += int(span.size)
                    self._replay_span(kn, cache, is_dac, span, skeys,
                                      sops, plan, probe_map, dkeys,
                                      dbuckets, out_values)
                    return
            # bounded planning chunks: the planner truncates itself at
            # the first op it cannot prove (wp.ops tells how far it
            # got), so planning work stays linear in the window
            end = min(span.size, 512)
            t0 = perf_counter()
            wp = planner(cache, kn, skeys[:end], sops[:end], span[:end],
                         plan, probe_map, dkeys, dbuckets, self.pool,
                         self.value_bytes, collect) \
                if planner is not None else None
            ENGINE_WALL["host_plan"] += perf_counter() - t0
            if wp is not None:
                end = wp.ops
                PLAN_STATS["planned_windows"] += 1
                PLAN_STATS["planned_ops"] += end
                self._apply_window_plan(kn, cache, wp, out_values)
            else:
                PLAN_STATS["replayed_windows"] += 1
                PLAN_STATS["replayed_ops"] += end
                self._replay_span(kn, cache, is_dac, span[:end],
                                  skeys[:end], sops[:end], plan,
                                  probe_map, dkeys, dbuckets,
                                  out_values)
            start += end

    def _replay_span(self, kn, cache, is_dac, span, skeys, sops, plan,
                     probe_map, dkeys, dbuckets, out_values) -> None:
        """Exact per-op replay of one span: classify with one
        kind-gather, split into maximal same-class runs, apply
        vectorizable runs in bulk (re-validated against the live cache
        at run boundaries), drop to the exact scalar op otherwise."""
        t0_wall = perf_counter()
        cls = np.where(sops == 0, cache.kind[skeys],
                       np.where(sops == 1, np.int8(3), np.int8(4)))
        m = span.size
        bnd = np.nonzero(cls[1:] != cls[:-1])[0] + 1
        starts = (0, *bnd.tolist())
        ends = (*bnd.tolist(), m)
        cls_l = cls.tolist()
        span_l = keys_l = None
        for s, e in zip(starts, ends):
            c = cls_l[s]
            if c == 2 and e - s >= 48:
                # a long value-hit run stays in numpy end to end
                self._vh_run_big(kn, cache, span[s:e], skeys[s:e],
                                 probe_map, dkeys, dbuckets, out_values)
                continue
            if span_l is None:
                span_l = span.tolist()
                keys_l = skeys.tolist()
            if c == 2:
                if is_dac:
                    self._vh_run(kn, cache, span_l[s:e], keys_l[s:e],
                                 probe_map, dkeys, dbuckets, out_values)
                else:
                    self._hit_run_static(kn, cache, span_l[s:e],
                                         keys_l[s:e], c, probe_map,
                                         dkeys, dbuckets, out_values)
            elif c == 1:
                if is_dac:
                    self._sc_run(kn, cache, span_l[s:e], keys_l[s:e],
                                 probe_map, dkeys, dbuckets, out_values)
                else:
                    self._hit_run_static(kn, cache, span_l[s:e],
                                         keys_l[s:e], c, probe_map,
                                         dkeys, dbuckets, out_values)
            elif c >= 3:
                if is_dac:
                    self._write_run(kn, cache, span_l[s:e], keys_l[s:e],
                                    c == 4, plan, out_values)
                else:
                    self._write_run_generic(kn, cache, span_l[s:e],
                                            keys_l[s:e], c == 4, plan,
                                            out_values)
            else:
                # predicted misses: exact scalar ops
                for p_, k in zip(span_l[s:e], keys_l[s:e]):
                    self._scalar_read_dac(kn, cache, k, p_, probe_map,
                                          dkeys, dbuckets, out_values)
        ENGINE_WALL["host_replay"] += perf_counter() - t0_wall

    def _apply_window_plan(self, kn, cache, wp, out_values) -> None:
        """Apply a planned window: bulk cache mutation via apply_plan,
        then the kn-side effects (stats, miss-RT EMA in op order,
        segcache puts/pops, collected read values)."""
        t0_wall = perf_counter()
        cache.apply_plan(wp)
        st = kn.stats
        st.ops += wp.ops
        st.reads += wp.reads
        st.writes += wp.writes
        st.rts += wp.rts
        if wp.ema_rts:
            ema = cache._ema
            a = cache.avg_miss_rts
            for r in wp.ema_rts:
                a += ema * (r - a)
            cache.avg_miss_rts = a
        segd = kn.segcache
        cap = kn.segcache_cap
        if wp.seg_replay is not None:
            vb = self.value_bytes
            for k, p in wp.seg_replay:
                if p is None:
                    segd.pop(k, None)
                else:
                    segd[k] = (p, vb)
                    segd.move_to_end(k)
                    while len(segd) > cap:
                        segd.popitem(last=False)
        elif wp.seg_puts is not None:
            ks, ps = wp.seg_puts
            vb = self.value_bytes
            segd.update(zip(ks, ((p, vb) for p in ps)))
            # C-level move_to_end sweep keeps last-put order; trimming
            # afterwards equals per-put trimming (LRU invariant)
            any(map(segd.move_to_end, ks))
            while len(segd) > cap:
                segd.popitem(last=False)
        if out_values is not None and wp.out_vals:
            for p, v in wp.out_vals:
                out_values[p] = v
        ENGINE_WALL["host_apply"] += perf_counter() - t0_wall

    def _vh_run(self, kn, cache, run_pos, run_keys, probe_map, dkeys,
                dbuckets, out_values) -> None:
        """A short run of predicted value hits: hit bookkeeping applied
        inline, with the live entry kind re-checked per op (an earlier
        op in the window may have moved a key); mispredictions take the
        exact scalar path in order."""
        kindarr = cache.kind
        heap = self.pool.heap_val
        st = kn.stats
        cnt = cache.count
        stp = cache.stamp
        ptr_l = cache.ptr
        clock = cache._clock
        collect = out_values is not None
        hits = 0
        for i in range(len(run_keys)):
            k = run_keys[i]
            if kindarr[k] != 2:
                cache._clock = clock
                self._scalar_read_dac(kn, cache, k, run_pos[i],
                                      probe_map, dkeys, dbuckets,
                                      out_values)
                clock = cache._clock
                continue
            cnt[k] += 1
            stp[k] = clock
            clock += 1
            hits += 1
            if collect:
                out_values[run_pos[i]] = heap[ptr_l[k]]
        cache._clock = clock
        cache.stats.value_hits += hits
        st.ops += hits
        st.reads += hits

    def _vh_run_big(self, kn, cache, run_pos, run_keys, probe_map, dkeys,
                    dbuckets, out_values) -> None:
        """A long run of predicted value hits: bulk-apply through
        bulk_value_hits with one vectorized validation gather per
        sub-run; mispredictions take the exact scalar path in order."""
        kindarr = cache.kind
        heap = self.pool.heap_val
        st = kn.stats
        while run_keys.size:
            okm = kindarr[run_keys] == 2
            b = run_keys.size if okm.all() else int(np.argmax(~okm))
            if b:
                cache.bulk_value_hits(run_keys[:b])
                st.ops += b
                st.reads += b
                if out_values is not None:
                    ptr_l = cache.ptr
                    for p_, k in zip(run_pos[:b].tolist(),
                                     run_keys[:b].tolist()):
                        out_values[p_] = heap[ptr_l[k]]
            if b == run_keys.size:
                return
            self._scalar_read_dac(kn, cache, int(run_keys[b]),
                                  int(run_pos[b]), probe_map, dkeys,
                                  dbuckets, out_values)
            run_pos = run_pos[b + 1:]
            run_keys = run_keys[b + 1:]

    def _sc_run(self, kn, cache, run_pos, run_keys, probe_map, dkeys,
                dbuckets, out_values) -> None:
        """A run of predicted shortcut hits: the hit bookkeeping and the
        always-promoting Eq. 1 transition (free space, or enough
        never-hit shortcut victims -- the common case on warm caches)
        run inline over the cache's lazy heaps with run-local state
        mirrors; undecided promotions and mispredictions drop to the
        exact library path with the mirrors synced around the call."""
        heap = self.pool.heap_val
        st = kn.stats
        cs = cache.stats
        heappush, heappop = heapq.heappush, heapq.heappop
        kind_a = cache.kind
        cnt = cache.count
        lenl = cache.length
        ptrl = cache.ptr
        stp = cache.stamp
        cap = cache.capacity
        used = cache.used
        zshort = cache._zero_shortcuts
        nvals = cache._nvals
        nshort = cache._nshort
        clock = cache._clock
        lru = cache._lru
        lfu = cache._lfu
        hist = cache._cnt_hist
        hmax = CNT_HIST_MAX
        nops = 0
        rts = 0.0
        shits = promos = demos = evics = 0
        collect = out_values is not None
        kl = run_keys
        pl_ = run_pos
        m = len(kl)
        i = 0
        while i < m:
            k = kl[i]
            if kind_a[k] != 1:
                # misprediction (an earlier op in this window moved the
                # key): sync mirrors, take the exact scalar path
                cache.used = used
                cache._zero_shortcuts = zshort
                cache._nvals = nvals
                cache._nshort = nshort
                cache._clock = clock
                self._scalar_read_dac(kn, cache, k, pl_[i], probe_map,
                                      dkeys, dbuckets, out_values)
                used = cache.used
                zshort = cache._zero_shortcuts
                nvals = cache._nvals
                nshort = cache._nshort
                clock = cache._clock
                lru = cache._lru
                lfu = cache._lfu
                i += 1
                continue
            c = cnt[k] + 1
            cnt[k] = c
            if c == 1:
                zshort -= 1
            hist[c - 1 if c <= hmax else hmax] -= 1
            hist[c if c < hmax else hmax] += 1
            shits += 1
            nops += 1
            rts += 1.0          # one-sided pointer chase
            if collect:
                out_values[pl_[i]] = heap[ptrl[k]]
            i += 1
            # Eq. 1 fast decision (exact: sufficient conditions)
            ln = lenl[k]
            vb = ln + 40        # VALUE_OVERHEAD_BYTES
            free = cap - used
            if free >= vb - 32:
                promote = True
            elif zshort >= -((free - vb + 32) // 32):
                promote = True  # victims all free: Eq. 1 rhs 0
            else:
                promote = None  # undecided: exact slow path
            if promote is None:
                cache.used = used
                cache._zero_shortcuts = zshort
                cache._nvals = nvals
                cache._nshort = nshort
                cache._clock = clock
                if cache._should_promote(k, c, ln):
                    cache._promote(k)
                    cs.promotions += 1
                used = cache.used
                zshort = cache._zero_shortcuts
                nvals = cache._nvals
                nshort = cache._nshort
                clock = cache._clock
                lru = cache._lru
                lfu = cache._lfu
                continue
            # ---- inline promote: shortcut -> value (Table 3) ----
            promos += 1
            kind_a[k] = 0
            used -= 32
            nshort -= 1
            hist[c if c < hmax else hmax] -= 1
            if used + vb > cap:
                # make space: demote LRU values, then evict LFU
                while used + vb > cap and nvals:
                    if len(lru) > 4 * nvals + 64:
                        cache._compact_lru()
                        lru = cache._lru
                    v = None
                    while lru:
                        st_, kk = heappop(lru)
                        if kind_a[kk] != 2:
                            continue               # stale: drop
                        cur = stp[kk]
                        if cur != st_:
                            heappush(lru, (cur, kk))   # refresh
                            continue
                        v = kk
                        break
                    if v is None:
                        break
                    used -= lenl[v] + 40
                    nvals -= 1
                    kind_a[v] = 0
                    demos += 1
                    if used + 32 + vb <= cap:
                        cv = cnt[v]
                        kind_a[v] = 1
                        heappush(lfu, (cv, v))
                        used += 32
                        nshort += 1
                        if cv == 0:
                            zshort += 1
                        hist[cv if cv < hmax else hmax] += 1
                while used + vb > cap and nshort:
                    if len(lfu) > 4 * nshort + 64:
                        cache._compact_lfu()
                        lfu = cache._lfu
                    v = None
                    while lfu:
                        ct_, kk = heappop(lfu)
                        if kind_a[kk] != 1:
                            continue
                        cur = cnt[kk]
                        if cur != ct_:
                            heappush(lfu, (cur, kk))
                            continue
                        v = kk
                        break
                    if v is None:
                        break
                    cv = cnt[v]
                    kind_a[v] = 0
                    used -= 32
                    nshort -= 1
                    if cv == 0:
                        zshort -= 1
                    hist[cv if cv < hmax else hmax] -= 1
                    evics += 1
            if used + vb > cap:
                # degenerate: cannot fit the value even after
                # demotions/evictions -> falls back to a shortcut
                # entry, exactly as _insert_value
                if used + 32 <= cap:
                    kind_a[k] = 1
                    heappush(lfu, (c, k))
                    used += 32
                    nshort += 1
                    hist[c if c < hmax else hmax] += 1
            else:
                kind_a[k] = 2
                stp[k] = clock
                # monotonic stamps exceed every record in the heap, so
                # appending keeps the heap invariant (O(1) vs O(log n))
                lru.append((clock, k))
                clock += 1
                used += vb
                nvals += 1
        cache.used = used
        cache._zero_shortcuts = zshort
        cache._nvals = nvals
        cache._nshort = nshort
        cache._clock = clock
        cs.shortcut_hits += shits
        cs.promotions += promos
        cs.demotions += demos
        cs.evictions += evics
        st.ops += nops
        st.reads += nops
        st.rts += rts

    def _scalar_read_dac(self, kn, cache, k, p, probe_map, dkeys, dbuckets,
                         out_values) -> None:
        """One exact non-replicated read against an ArrayDAC KN --
        read() minus routing, with the batched probe prefetch in place
        of the live index traversal when still provably fresh."""
        pool = self.pool
        st = kn.stats
        st.ops += 1
        st.reads += 1
        rts = 0.0
        value = None
        hit = cache.lookup(k)
        if hit is not None:
            kind, ptr, _len = hit
            if kind != "value":
                rts = 1.0                          # one-sided pointer chase
            value = pool.heap_val[ptr]
        else:
            seg = kn.segcache.get(k)
            if seg is not None:
                ptr, length = seg
                value = pool.heap_val[ptr]         # local segment: 0 RTs
                cache.fill_after_write(k, ptr, length, segment_cached=True)
            else:
                pr = probe_map.get(p)
                if pr is None or k in dkeys or pr[2] in dbuckets:
                    ptr, probes = pool.index_lookup(k)
                else:
                    ptr, probes = pr[0], pr[1]
                if ptr is None:
                    st.rts += probes               # index traversal only
                    return
                rts = probes + 1.0                 # traversal + value fetch
                cache.note_miss_rts(rts)
                cache.fill_after_miss(k, ptr, pool.heap_len[ptr])
                value = pool.heap_val[ptr]
        st.rts += rts
        if out_values is not None:
            out_values[p] = value

    def _write_run(self, kn, cache, run_pos, run_keys, delete, plan,
                   out_values) -> None:
        """A run of same-KN writes: the log plane is already staged
        (pointers, flush RTs, segment entries), leaving the segcache
        update and the cache fill -- fill_after_write(segment_cached)
        inlined over the run-local state mirrors (value entry when it
        fits, else a shortcut with the full demote-LRU/evict-LFU
        make-space loop, exactly as the library path)."""
        st = kn.stats
        nrun = len(run_pos)
        st.ops += nrun
        st.writes += nrun
        wrank_l = plan.wrank_l
        rts_l = plan.rts_l
        ptrs_l = plan.ptrs_l
        segd = kn.segcache
        if delete:
            rts = 0.0
            for p_, k in zip(run_pos, run_keys):
                rts += rts_l[wrank_l[p_]]
                cache.invalidate(k)
                segd.pop(k, None)
            st.rts += rts
            return
        segcap = kn.segcache_cap
        vbytes = self.value_bytes
        vbb = vbytes + 40              # VALUE_OVERHEAD_BYTES
        heappush, heappop = heapq.heappush, heapq.heappop
        kind_a = cache.kind
        cnt = cache.count
        lenl = cache.length
        ptrl = cache.ptr
        stp = cache.stamp
        cap = cache.capacity
        used = cache.used
        zshort = cache._zero_shortcuts
        nvals = cache._nvals
        nshort = cache._nshort
        clock = cache._clock
        lru = cache._lru
        lfu = cache._lfu
        hist = cache._cnt_hist
        hmax = CNT_HIST_MAX
        demos = evics = 0
        rts = 0.0
        for p_, k in zip(run_pos, run_keys):
            ptr = ptrs_l[wrank_l[p_]]
            rts += rts_l[wrank_l[p_]]
            segd[k] = (ptr, vbytes)
            segd.move_to_end(k)
            while len(segd) > segcap:
                segd.popitem(last=False)
            # ---- fill_after_write(k, ptr, vbytes, segment_cached) ----
            kd = kind_a[k]
            if kd == 0:
                cpri = 0
            elif kd == 1:
                cpri = cnt[k]
                kind_a[k] = 0
                used -= 32
                nshort -= 1
                if cpri == 0:
                    zshort -= 1
                hist[cpri if cpri < hmax else hmax] -= 1
            else:
                cpri = cnt[k]
                kind_a[k] = 0
                used -= lenl[k] + 40
                nvals -= 1
            if used + vbb <= cap:
                # the value entry fits: insert, no space-making needed
                kind_a[k] = 2
                ptrl[k] = ptr
                lenl[k] = vbytes
                cnt[k] = cpri
                stp[k] = clock
                # monotonic stamp: plain append keeps the heap invariant
                lru.append((clock, k))
                clock += 1
                used += vbb
                nvals += 1
                continue
            # shortcut entry: _make_space(32), demote-first (Table 3)
            while used + 32 > cap and nvals:
                if len(lru) > 4 * nvals + 64:
                    cache._compact_lru()
                    lru = cache._lru
                v = None
                while lru:
                    st_, kk = heappop(lru)
                    if kind_a[kk] != 2:
                        continue                   # stale: drop
                    cur = stp[kk]
                    if cur != st_:
                        heappush(lru, (cur, kk))   # refresh
                        continue
                    v = kk
                    break
                if v is None:
                    break
                used -= lenl[v] + 40
                nvals -= 1
                kind_a[v] = 0
                demos += 1
                if used + 32 + 32 <= cap:
                    cv = cnt[v]
                    kind_a[v] = 1
                    heappush(lfu, (cv, v))
                    used += 32
                    nshort += 1
                    if cv == 0:
                        zshort += 1
                    hist[cv if cv < hmax else hmax] += 1
            while used + 32 > cap and nshort:
                if len(lfu) > 4 * nshort + 64:
                    cache._compact_lfu()
                    lfu = cache._lfu
                v = None
                while lfu:
                    ct_, kk = heappop(lfu)
                    if kind_a[kk] != 1:
                        continue
                    cur = cnt[kk]
                    if cur != ct_:
                        heappush(lfu, (cur, kk))
                        continue
                    v = kk
                    break
                if v is None:
                    break
                cv = cnt[v]
                kind_a[v] = 0
                used -= 32
                nshort -= 1
                if cv == 0:
                    zshort -= 1
                hist[cv if cv < hmax else hmax] -= 1
                evics += 1
            if used + 32 <= cap:
                kind_a[k] = 1
                ptrl[k] = ptr
                lenl[k] = vbytes
                cnt[k] = cpri
                heappush(lfu, (cpri, k))
                used += 32
                nshort += 1
                if cpri == 0:
                    zshort += 1
                hist[cpri if cpri < hmax else hmax] += 1
            # else: cache smaller than one entry: degenerate, skip
        st.rts += rts
        cache.used = used
        cache._zero_shortcuts = zshort
        cache._nvals = nvals
        cache._nshort = nshort
        cache._clock = clock
        cs = cache.stats
        cs.demotions += demos
        cs.evictions += evics

    def _hit_run_static(self, kn, cache, run_pos, run_keys, kd, probe_map,
                        dkeys, dbuckets, out_values) -> None:
        """A run of predicted static-cache hits (value or shortcut):
        each hit is a recency bump (+1 RT for shortcuts), re-validated
        per op; mispredictions take the exact scalar path."""
        kindarr = cache.kind
        heap = self.pool.heap_val
        st = kn.stats
        stp = cache.stamp
        ptr_l = cache.ptr
        clock = cache._clock
        collect = out_values is not None
        hits = 0
        for i in range(len(run_keys)):
            k = run_keys[i]
            if kindarr[k] != kd:
                cache._clock = clock
                self._scalar_read_dac(kn, cache, k, run_pos[i],
                                      probe_map, dkeys, dbuckets,
                                      out_values)
                clock = cache._clock
                continue
            stp[k] = clock
            clock += 1
            hits += 1
            if collect:
                out_values[run_pos[i]] = heap[ptr_l[k]]
        cache._clock = clock
        st.ops += hits
        st.reads += hits
        if kd == 2:
            cache.stats.value_hits += hits
        else:
            cache.stats.shortcut_hits += hits
            st.rts += float(hits)          # one-sided pointer chase each

    def _write_run_generic(self, kn, cache, run_pos, run_keys, delete,
                           plan, out_values) -> None:
        """A run of same-KN writes against a non-DAC cache: staged log
        plane + segcache update + the library fill per op."""
        st = kn.stats
        nrun = len(run_pos)
        st.ops += nrun
        st.writes += nrun
        wrank_l = plan.wrank_l
        rts_l = plan.rts_l
        ptrs_l = plan.ptrs_l
        segd = kn.segcache
        rts = 0.0
        if delete:
            for p_, k in zip(run_pos, run_keys):
                rts += rts_l[wrank_l[p_]]
                cache.invalidate(k)
                segd.pop(k, None)
            st.rts += rts
            return
        segcap = kn.segcache_cap
        vb = self.value_bytes
        for p_, k in zip(run_pos, run_keys):
            r = wrank_l[p_]
            ptr = ptrs_l[r]
            rts += rts_l[r]
            segd[k] = (ptr, vb)
            segd.move_to_end(k)
            while len(segd) > segcap:
                segd.popitem(last=False)
            cache.fill_after_write(k, ptr, vb, segment_cached=True)
        st.rts += rts

    def _exec_rep_op(self, p, kinds, keys, kn_ids, names, plan, dkeys,
                     out_values) -> None:
        """One replicated-key op at its exact global position (the
        indirection slot is shared across owners, so these synchronize
        globally): reads take the generic read() path; writes replay
        write()'s indirection CAS against the staged log pointer."""
        k = int(keys[p])
        kn = self.kns[names[int(kn_ids[p])]]
        if kinds[p] == 0:
            r = self.read(k, kn.name)
            if out_values is not None:
                out_values[p] = r[0]
            return
        delete = kinds[p] == 2
        st = kn.stats
        st.ops += 1
        st.writes += 1
        rank = int(plan.wrank[p])
        rts = float(plan.rts[rank])
        ptr = int(plan.ptrs[rank])
        length = 0 if delete else self.value_bytes
        replicated = (self.variant.selective_replication
                      and self.ownership.is_replicated(k) and not delete)
        with sanitize.owned(kn.name):
            if replicated:
                # atomically swing the indirect pointer: one-sided CAS
                expect = self.pool.read_indirect(k)
                self.pool.cas_indirect(k, expect, ptr,
                                       kn=kn.name, token=kn.fence_token)
                rts += 1.0
                kn.cache.update_pointer(k, ptr, length)
                dkeys.add(k)   # index_lookup(k) now resolves differently
            elif delete:
                kn.cache.invalidate(k)
                kn.segcache.pop(k, None)
            else:
                kn._segcache_put(k, ptr, length)
                kn.cache.fill_after_write(k, ptr, length,
                                          segment_cached=True)
        st.rts += rts

    @staticmethod
    def _kn_groups(pos: np.ndarray, kn_ids: np.ndarray):
        """Split sorted global positions into per-KN groups (each group
        keeps ascending op order)."""
        if not pos.size:
            return
        ids = kn_ids[pos]
        order = np.argsort(ids, kind="stable")
        sp = pos[order]
        bounds = np.nonzero(np.diff(ids[order]))[0] + 1
        yield from np.split(sp, bounds)

    def _execute_batch_clover(self, kinds, keys, value, values,
                              blocked_kns, out_values,
                              req_ids=None) -> "BatchResult":
        """The batched Clover plane (shared-everything, version-chain
        cache): client routing draws the rng per op exactly as the
        scalar path, version-counter checks and shortcut fills run
        against the ArrayCloverCache, and the per-write merge-all
        (Clover updates metadata in place) is staged -- superseded
        pointers invalidate eagerly at their op position through a
        pending-index overlay, the CLHT bucket updates land once at
        batch end via the planned insert_batch (plan_merge_window ->
        apply_merge_plan, scalar replay past a plan's self-truncation
        point). Requires (and leaves)
        empty active logs; statistics are op-for-op identical to the
        per-op path (property-tested)."""
        # shared-everything: every KN serves (and stamps) any key, so
        # there is no ownership partition for the sanitizer to enforce
        with sanitize.management():
            return self._execute_batch_clover_at(
                kinds, keys, value, values, blocked_kns, out_values,
                req_ids)

    def _execute_batch_clover_at(self, kinds, keys, value, values,
                                 blocked_kns, out_values,
                                 req_ids=None) -> "BatchResult":
        pool = self.pool
        versions = self.versions
        heap = pool.heap_val
        heap_len = pool.heap_len
        heap_seg = pool.heap_seg
        gc = pool.gc
        kns = self.kns
        names = [n for n, k in kns.items() if k.alive]
        n = keys.shape[0]
        if not names:
            return BatchResult(0, 0, {}, keys[:0], out_values)
        choice = self.rng.choice
        kn_names = [choice(names) for _ in range(n)]
        blocked = set(blocked_kns)
        ptr0, _probes = pool.index_lookup_batch(keys)
        if not kinds.any():
            res = self._clover_read_batch(keys, kn_names, names, blocked,
                                          ptr0, out_values)
            if res is not None:
                return res
        ptr0_l = ptr0.tolist()
        keys_l = keys.tolist()
        kinds_l = kinds.tolist()
        vb = self.value_bytes
        cap = pool.segment_capacity
        collect = out_values is not None
        pend: dict[int, int] = {}      # key -> latest in-batch ptr (-1 del)
        wrote: set[str] = set()
        per_kn: dict[str, int] = {}
        exec_idx: list[int] = []
        writes = 0
        ms = 0
        vbump = 0                      # index.version bumps the per-op
        v0 = pool.index.version        # sequence would have made
        for i in range(n):
            nm = kn_names[i]
            if nm in blocked:
                continue
            k = keys_l[i]
            kn = kns[nm]
            exec_idx.append(i)
            per_kn[nm] = per_kn.get(nm, 0) + 1
            st = kn.stats
            if not kn.available:
                st.refused += 1
                if kinds_l[i]:
                    writes += 1
                continue
            cache = kn.cache
            if kinds_l[i] == 0:
                # ---- _clover_read, staged index ----
                st.ops += 1
                st.reads += 1
                cur = versions.get(k, 0)
                cached = cache.lookup(k)
                rts = 0.0
                if cached is None:
                    ms += 1            # two-sided RPC to metadata server
                    rts = 1.0
                p_ = pend.get(k, ptr0_l[i])
                if p_ < 0:
                    st.rts += rts
                    continue
                stale = cur - cached \
                    if cached is not None and cur > cached else 0
                # walk the version chain from the cached cursor
                rts += 2.0 + stale
                with sanitize.owned(kn.name):
                    cache.fill(k, cur)
                if collect:
                    out_values[i] = heap[p_]
                st.rts += rts
                continue
            # ---- _clover_write + staged merge-all ----
            writes += 1
            delete = kinds_l[i] == 2
            st.ops += 1
            st.writes += 1
            length = 0 if delete else vb
            ptr = len(heap)
            heap.append(None if delete
                        else self._value_at(i, value, values))
            heap_len.append(length)
            seg = pool.new_segment(nm)
            seg.entries.append((-k - 1 if delete else k, ptr))
            seg.sealed.append(True)
            rid = -1 if req_ids is None else int(req_ids[i])
            seg.reqs.append(rid)
            seg.gens.append(pool.fence.get(nm, 0))
            if rid >= 0:
                pool.req_index[rid] = ptr
            seg.valid = 1
            seg.merged_upto = 1
            heap_seg.append(seg)
            wrote.add(nm)
            gc.entries_merged += 1     # Clover merges each write in place
            old = pend.get(k)
            if old is None:
                old = ptr0_l[i]
            if delete:
                seg.valid -= 1         # tombstone consumes its own entry
                if old >= 0:
                    vbump += 1
                    pool._invalidate_ptr(old)
                pend[k] = -1
            else:
                vbump += 1
                if old >= 0 and old != ptr:
                    pool._invalidate_ptr(old)
                pend[k] = ptr
            versions[k] = versions.get(k, 0) + 1
            with sanitize.owned(kn.name):
                cache.fill(k, versions[k])
            st.rts += 2.0              # out-of-place append + link/CAS
        # land the final index state (grouped bucket update); superseded
        # pointers were invalidated at their op positions above
        if pend:
            ins = [(k, p) for k, p in pend.items() if p >= 0]
            if ins:
                ka = np.fromiter((k for k, _ in ins), np.int64, len(ins))
                pa = np.fromiter((p for _, p in ins), np.int64, len(ins))
                pool.index.insert_batch(ka, pa)
            for k, p in pend.items():
                if p < 0:
                    pool.index.delete(k)
            # align the version counter with the per-op merge cadence
            pool.index.version = v0 + vbump
        for nm in wrote:
            pool.segments[nm] = [pool.new_segment(nm)]
        self.ms_ops += ms
        idx = np.asarray(exec_idx, dtype=np.int64)
        return BatchResult(len(exec_idx), writes, per_kn, keys[idx],
                           out_values)

    def _clover_read_batch(self, keys, kn_names, names, blocked, ptr0,
                           out_values) -> "BatchResult | None":
        """Planned read-only Clover batch: each KN's slice of the batch
        is planned as one bulk cache transition (plan_clover_reads) and
        applied through ArrayCloverCache.apply_plan.  Returns None when
        any KN's plan could evict (the per-op loop then runs instead);
        nothing is mutated until every plan is in hand."""
        kns = self.kns
        versions = self.versions
        n = keys.shape[0]
        keys_l = keys.tolist()
        vget = versions.get
        vers = np.fromiter((vget(k, 0) for k in keys_l), np.int64, n)
        found = ptr0 >= 0
        idx = {nm: j for j, nm in enumerate(names)}
        kn_ids = np.fromiter(map(idx.__getitem__, kn_names), np.int64, n)
        bl = np.zeros(len(names), bool)
        un = np.zeros(len(names), bool)
        for j, nm in enumerate(names):
            bl[j] = nm in blocked
            un[j] = not kns[nm].available
        execm = ~bl[kn_ids]
        live = execm & ~un[kn_ids]
        plans = []
        for j, nm in enumerate(names):
            grp = np.flatnonzero(live & (kn_ids == j))
            if not grp.size:
                plans.append((nm, grp, None))
                continue
            wp = plan_clover_reads(kns[nm].cache, keys[grp], vers[grp],
                                   found[grp])
            if wp is None:
                return None
            plans.append((nm, grp, wp))
        ms = 0
        per_kn: dict[str, int] = {}
        for j, nm in enumerate(names):
            cnt = int(execm[kn_ids == j].sum())
            if cnt:
                per_kn[nm] = cnt
        for nm, grp, wp in plans:
            kn = kns[nm]
            st = kn.stats
            refused = int((execm & un[kn_ids] & (kn_ids == idx[nm]))
                          .sum())
            st.refused += refused
            if wp is None:
                continue
            with sanitize.owned(nm):
                kn.cache.apply_plan(wp)
            st.ops += int(grp.size)
            st.reads += int(grp.size)
            st.rts += wp.rts
            ms += wp.misses
            if out_values is not None:
                heap = self.pool.heap_val
                for p_, pt in zip(grp.tolist(), ptr0[grp].tolist()):
                    if pt >= 0:
                        out_values[p_] = heap[pt]
        self.ms_ops += ms
        eidx = np.flatnonzero(execm)
        return BatchResult(int(eidx.size), 0, per_kn, keys[eidx],
                           out_values)

    def _execute_batch_fused(self, kinds, keys, value, values, blocked_kns,
                             out_values, req_ids=None):
        blocked = set(blocked_kns)
        per_kn: dict[str, int] = {}
        writes = 0
        exec_idx = []
        read, write, route = self.read, self.write, self.route
        for i in range(keys.shape[0]):
            key = int(keys[i])
            try:
                kn = route(key)
            except KeyError:
                continue
            if kn in blocked:
                continue
            exec_idx.append(i)
            per_kn[kn] = per_kn.get(kn, 0) + 1
            rid = -1 if req_ids is None else int(req_ids[i])
            if kinds[i] == 0:
                r = read(key, kn)
                if out_values is not None:
                    out_values[i] = r[0]
            elif kinds[i] == 2:
                writes += 1
                write(key, None, kn, delete=True, req_id=rid)
            else:
                writes += 1
                write(key, self._value_at(i, value, values), kn,
                      req_id=rid)
        idx = np.asarray(exec_idx, dtype=np.int64)
        return BatchResult(len(exec_idx), writes, per_kn, keys[idx],
                           out_values)

    @staticmethod
    def _value_at(i: int, value, values):
        if values is None:
            return value
        if callable(values):
            return values(i)
        return values[i]

    def batch_read(self, keys, collect_values: bool = True):
        """Batched read entry point: returns (values, result)."""
        keys = np.asarray(keys, dtype=np.int64)
        res = self.execute_batch(np.zeros(keys.shape[0], np.uint8), keys,
                                 collect_values=collect_values)
        return res.values, res

    def batch_write(self, keys, values):
        """Batched write entry point: returns the BatchResult."""
        keys = np.asarray(keys, dtype=np.int64)
        return self.execute_batch(np.ones(keys.shape[0], np.uint8), keys,
                                  values=values)

    # ---------------------------------------------------------------------
    # background work + bookkeeping
    # ---------------------------------------------------------------------
    def advance_merge(self, ops: int) -> int:
        return self.pool.merge_budget(ops)

    def load(self, items, warm: bool = False) -> None:
        """Bulk-load the dataset (untimed, as in the paper's load phase).
        ``warm=True`` reproduces the load-through-KN effect: under OP the
        owner inserted every key it owns, so it holds a shortcut for
        free; under shared-everything each key was handled by one
        arbitrary KN."""
        items = list(items)
        self.pool.bulk_load((k, v, self.value_bytes) for k, v in items)
        if not warm:
            return
        keys = [k for k, _ in items]
        names = list(self.kns)
        with sanitize.management():     # warm load fills any KN's cache
            for k in keys:
                ptr, _ = self.pool.index_lookup(k)
                if ptr is None:
                    continue
                if self.variant.name == "clover":
                    kn = self.kns[names[stable_hash(("load", k))
                                        % len(names)]]
                    kn.cache.fill(k, self.versions.get(k, 0))
                else:
                    owner = self.ownership.primary(k)
                    self.kns[owner].cache.fill_after_write(
                        k, ptr, self.value_bytes, segment_cached=False)

    def aggregate_stats(self) -> dict:
        tot_ops = sum(k.stats.ops for k in self.kns.values())
        tot_rts = sum(k.stats.rts for k in self.kns.values())
        caches = [k.cache.stats for k in self.kns.values()
                  if hasattr(k.cache, "stats")]
        lookups = sum(c.lookups for c in caches)
        hits = sum(c.value_hits + c.shortcut_hits for c in caches)
        vhits = sum(c.value_hits for c in caches)
        return {
            "ops": tot_ops,
            "rts_per_op": tot_rts / tot_ops if tot_ops else 0.0,
            "hit_ratio": hits / lookups if lookups else 0.0,
            "value_hit_ratio": vhits / lookups if lookups else 0.0,
            "write_stalls": sum(k.stats.write_stalls
                                for k in self.kns.values()),
            "num_kns": len(self.kns),
        }

    def reset_stats(self) -> None:
        for kn in self.kns.values():
            kn.stats = KNStats()
            if hasattr(kn.cache, "stats"):
                kn.cache.stats = CacheStats()
        self.ms_ops = 0
