"""Linearizability checker for key-value histories (paper Sec. 3.2).

DINOMO guarantees linearizable reads/writes. Because ownership
partitioning gives every key an independent, single-owner timeline,
linearizability decomposes per key (locality property of
linearizability, Herlihy & Wing): we check each key's sub-history with
an exhaustive Wing-Gong search (histories in tests are small).

Events carry real-time invocation/response intervals; concurrent
operations may be ordered either way, sequential ones must respect
real time.
"""

from __future__ import annotations

from dataclasses import dataclass
from itertools import permutations


@dataclass(frozen=True)
class Op:
    kind: str          # "read" | "write"
    key: int
    value: object      # written value, or value returned by the read
    invoke: float
    respond: float
    client: str = "c0"


def _check_sequence(ops: list[Op], initial) -> bool:
    """Is this total order a legal sequential KV execution?"""
    cur = initial
    for op in ops:
        if op.kind == "write":
            cur = op.value
        else:
            if op.value != cur:
                return False
    return True


def _respects_realtime(order: list[Op]) -> bool:
    for i, a in enumerate(order):
        for b in order[i + 1:]:
            if b.respond < a.invoke:     # b finished before a started
                return False
    return True


def check_key_history(ops: list[Op], initial=None,
                      max_exhaustive: int = 8) -> bool:
    """True iff the per-key history is linearizable."""
    ops = sorted(ops, key=lambda o: o.invoke)
    if len(ops) <= max_exhaustive:
        for perm in permutations(ops):
            order = list(perm)
            if _respects_realtime(order) and _check_sequence(order, initial):
                return True
        return False
    # larger histories: greedy DFS over linearization points
    return _dfs(ops, initial)


def _dfs(pending: list[Op], value) -> bool:
    if not pending:
        return True
    # candidates: ops whose invocation precedes every other response
    min_resp = min(o.respond for o in pending)
    for i, op in enumerate(pending):
        if op.invoke > min_resp:
            continue
        if op.kind == "read" and op.value != value:
            continue
        rest = pending[:i] + pending[i + 1:]
        nxt = op.value if op.kind == "write" else value
        if _dfs(rest, nxt):
            return True
    return False


def check_history(ops: list[Op], initial=None) -> dict[int, bool]:
    """Check a full multi-key history; returns per-key verdicts.
    ``initial`` may be a scalar (same initial value for all keys), a
    dict keyed by key, or a callable key -> value."""
    by_key: dict[int, list[Op]] = {}
    for op in ops:
        by_key.setdefault(op.key, []).append(op)
    def init_of(k):
        if callable(initial):
            return initial(k)
        if isinstance(initial, dict):
            return initial.get(k)
        return initial
    return {k: check_key_history(v, init_of(k)) for k, v in by_key.items()}
