"""Linearizability checker for key-value histories (paper Sec. 3.2).

DINOMO guarantees linearizable reads/writes. Because ownership
partitioning gives every key an independent, single-owner timeline,
linearizability decomposes per key (locality property of
linearizability, Herlihy & Wing): we check each key's sub-history with
an exhaustive Wing-Gong search (histories in tests are small).

Events carry real-time invocation/response intervals; concurrent
operations may be ordered either way, sequential ones must respect
real time.

Open-loop histories add *indeterminate* operations (``status=
"maybe"``): a write whose client timed out may or may not have taken
effect.  An indeterminate op has no response, so it never real-time-
precedes anything, and the checker may either linearize it (its effect
landed after invocation) or exclude it entirely (it never applied) --
the standard treatment of info/timeout ops in Jepsen-style checkers.
Shed operations are guaranteed clean no-ops and should simply be left
out of the history (the request plane asserts their request IDs never
registered).

Fenced operations (``status="fenced"``) are writes a stale-epoch owner
attempted after an ownership handoff: the DPM fence rejected them as
guaranteed no-ops (``FencedWrite``), so the checker *drops* them from
the history before searching.  This is deliberately stronger than
``"maybe"``: if a fence ever leaked and a reader observed a zombie's
value, no linearization can explain the read and the history fails --
whereas an indeterminate op could legally be linearized, masking the
leak."""

from __future__ import annotations

import math
from dataclasses import dataclass
from itertools import permutations


@dataclass(frozen=True)
class Op:
    kind: str          # "read" | "write"
    key: int
    value: object      # written value, or value returned by the read
    invoke: float
    respond: float
    client: str = "c0"
    # "ok" (definite) | "maybe" (indeterminate) | "fenced" (guaranteed
    # no-op: a stale-epoch write the DPM fence rejected)
    status: str = "ok"


def _eff_respond(op: Op) -> float:
    """Indeterminate ops have no observed response: they constrain no
    real-time order (their linearization point can be arbitrarily
    late)."""
    return math.inf if op.status != "ok" else op.respond


def _check_sequence(ops: list[Op], initial) -> bool:
    """Is this total order a legal sequential KV execution?"""
    cur = initial
    for op in ops:
        if op.kind == "write":
            cur = op.value
        else:
            if op.value != cur:
                return False
    return True


def _respects_realtime(order: list[Op]) -> bool:
    for i, a in enumerate(order):
        for b in order[i + 1:]:
            if _eff_respond(b) < a.invoke:   # b finished before a started
                return False
    return True


def check_key_history(ops: list[Op], initial=None,
                      max_exhaustive: int = 8) -> bool:
    """True iff the per-key history is linearizable.  Ops with
    ``status="maybe"`` may be included or excluded by the search;
    ``status="fenced"`` ops are guaranteed no-ops and are dropped."""
    ops = sorted((o for o in ops if o.status != "fenced"),
                 key=lambda o: o.invoke)
    if any(o.status != "ok" for o in ops) or len(ops) > max_exhaustive:
        return _dfs(ops, initial)
    for perm in permutations(ops):
        order = list(perm)
        if _respects_realtime(order) and _check_sequence(order, initial):
            return True
    return False


def _dfs(pending: list[Op], value) -> bool:
    if not pending:
        return True
    # candidates: ops whose invocation precedes every other response
    min_resp = min(_eff_respond(o) for o in pending)
    for i, op in enumerate(pending):
        if op.invoke > min_resp:
            continue
        if op.kind == "read" and op.value != value:
            continue
        rest = pending[:i] + pending[i + 1:]
        nxt = op.value if op.kind == "write" else value
        if _dfs(rest, nxt):
            return True
    # exclusion branches: an indeterminate op may simply never have
    # taken effect -- drop it and retry (exclusions commute, and test
    # histories are small, so the duplicate exploration is acceptable)
    for i, op in enumerate(pending):
        if op.status != "ok":
            if _dfs(pending[:i] + pending[i + 1:], value):
                return True
    return False


def check_history(ops: list[Op], initial=None) -> dict[int, bool]:
    """Check a full multi-key history; returns per-key verdicts.
    ``initial`` may be a scalar (same initial value for all keys), a
    dict keyed by key, or a callable key -> value."""
    by_key: dict[int, list[Op]] = {}
    for op in ops:
        by_key.setdefault(op.key, []).append(op)
    def init_of(k):
        if callable(initial):
            return initial(k)
        if isinstance(initial, dict):
            return initial.get(k)
        return initial
    return {k: check_key_history(v, init_of(k)) for k, v in by_key.items()}
