# DINOMO's contribution, reproduced: ownership partitioning, adaptive
# caching, selective replication, log-structured writes w/ async merge,
# and the M-node policy engine -- on real data structures with exact RT
# accounting (the JAX/Pallas data plane lives in clht.py / log.py and
# src/repro/kernels; the serving integration in src/repro/kvcache).
from .cluster import (CLOVER, DINOMO, DINOMO_N, DINOMO_S, VARIANTS,
                      ArrayCloverCache, BatchResult, CloverCache,
                      DinomoCluster, VariantConfig)
from .dac import ArrayDAC, ArrayStaticCache, DAC, StaticCache
from .dpm_pool import DPMPool, FencedWrite
from .faults import (ALL_POINTS, ARMABLE_POINTS, CRASH_POINTS,
                     FaultPlane, KNCrash, LOG_MERGE_POINTS, Partition,
                     SlowSpec)
from .hashring import HashRing, stable_hash
from .linearizability import Op, check_history, check_key_history
from .mnode import Action, EpochStats, PolicyConfig, PolicyEngine
from .netmodel import (ArrivalProcess, DEFAULT_MODEL, NetModel,
                       PhasedArrival)
from .ownership import OwnershipMap, ReconfigEvent
from .requestplane import (OpRecord, RequestPlane, RequestPlaneConfig,
                           RequestPlaneResult)
from .simulate import TimedSimulation
from .transition import (PLAN_STATS, DacWindowPlan, StaticWindowPlan,
                         CloverReadPlan, plan_clover_reads,
                         plan_dac_window, plan_static_window,
                         reset_plan_stats)

__all__ = [
    "DinomoCluster", "VariantConfig", "BatchResult", "DINOMO",
    "DINOMO_S", "DINOMO_N",
    "CLOVER", "VARIANTS", "DAC", "ArrayDAC", "ArrayStaticCache",
    "StaticCache", "CloverCache", "ArrayCloverCache", "DPMPool",
    "FaultPlane", "KNCrash", "CRASH_POINTS", "ALL_POINTS",
    "ARMABLE_POINTS", "LOG_MERGE_POINTS", "FencedWrite", "Partition",
    "SlowSpec",
    "HashRing",
    "stable_hash", "Op", "check_history", "check_key_history", "Action",
    "EpochStats", "PolicyConfig", "PolicyEngine", "NetModel",
    "DEFAULT_MODEL", "ArrivalProcess", "PhasedArrival", "OpRecord",
    "RequestPlane", "RequestPlaneConfig", "RequestPlaneResult",
    "OwnershipMap", "ReconfigEvent", "TimedSimulation",
    "PLAN_STATS", "DacWindowPlan", "StaticWindowPlan", "CloverReadPlan",
    "plan_dac_window", "plan_static_window", "plan_clover_reads",
    "reset_plan_stats",
]
