"""Timed cluster simulation for the elasticity experiments (Figs. 6-8).

Drives a DinomoCluster through wall-clock time: clients offer load,
sampled operations run against the real data structures (so hit ratios
and RTs/op are measured, not assumed), the M-node policy engine makes
decisions every epoch, and reconfigurations/failures inject the
protocol's real unavailability windows (synchronous merge for DINOMO,
data reorganization for DINOMO-N, membership refresh for Clover).
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field

import numpy as np

from .cluster import DinomoCluster, VariantConfig, DINOMO
from .mnode import EpochStats, PolicyConfig
from .netmodel import NetModel, DEFAULT_MODEL


@dataclass
class TimePoint:
    t: float
    throughput: float
    avg_latency: float
    p99_latency: float
    num_kns: int
    offered: float
    events: list[str] = field(default_factory=list)


@dataclass
class Outage:
    """A KN (or the whole cluster) unavailable until ``until``."""
    node: str | None
    until: float
    reason: str


class TimedSimulation:
    def __init__(self, cluster: DinomoCluster, workload,
                 model: NetModel = DEFAULT_MODEL, dt: float = 1.0,
                 sample_ops: int = 20_000, seed: int = 0,
                 dataset_bytes: float | None = None,
                 batched: bool = True, faults=None,
                 engine: str | None = None):
        # the sampled working set stands in for a paper-scale dataset;
        # reorganization physics (Dinomo-N) uses the represented bytes
        self.dataset_bytes = dataset_bytes
        """``workload(t, rng, n)`` yields n (op, key) pairs for time t
        -- either a list of tuples or a (kinds, keys) array pair (see
        Workload.timed_batched). ``batched=True`` drives the sampled
        ops through DinomoCluster.execute_batch (the vectorized data
        plane, statistically identical to the per-op loop);
        ``batched=False`` keeps the per-op loop for equivalence tests.
        The raised ``sample_ops`` default leans on the batched plane to
        sample closer to paper-scale op counts per epoch."""
        self.c = cluster
        self.workload = workload
        self.model = model
        self.dt = dt
        self.sample_ops = sample_ops
        self.batched = batched
        # batch-engine selection forwarded to execute_batch (None/"host"
        # -> host window engine, "jit" -> compiled batch executor)
        self.engine = engine
        self.rng = np.random.default_rng(seed)
        self.now = 0.0
        self.outages: list[Outage] = []
        self.trace: list[TimePoint] = []
        # optional FaultPlane: perturbs failure detection (delayed
        # heartbeats) -- the pool-level crash points attach to the pool
        self.faults = faults
        # operator-visible event timeline: guarded no-ops (e.g. refusing
        # to fail/remove the last alive KN), injected faults, and the
        # open-loop request plane's sheds/retries/timeouts.  Stable
        # schema: every entry is a dict with at least {"t": <simulated
        # seconds>, "kind": <event kind>}, plus kind-specific fields --
        # so scenario/latency reports can correlate sheds, retries,
        # crashes, and recoveries on one timeline.
        self.event_log: list[dict] = []
        # per-epoch key-frequency accumulator, sparse: sorted key array
        # + aligned counts, merged once per step -- top-k extraction is
        # one argpartition over the distinct sampled keys instead of
        # nlargest over a dict of every sampled key (which dominated
        # the batched plane's step cost on low-skew workloads)
        self._ef_keys = np.empty(0, np.int64)
        self._ef_cnts = np.empty(0, np.int64)
        self._epoch_total = 0.0
        self._next_epoch = cluster.mnode.cfg.epoch_s

    def log_event(self, kind: str, **fields) -> dict:
        """Append one schema'd event to the timeline and return it."""
        ev = {"t": round(self.now, 6), "kind": kind, **fields}
        self.event_log.append(ev)
        return ev

    def _freq_add(self, u: np.ndarray, cnt: np.ndarray) -> None:
        """Fold one step's (sorted unique keys, counts) into the epoch
        accumulator (one sorted merge)."""
        if self._ef_keys.size == 0:
            self._ef_keys = u.astype(np.int64)
            self._ef_cnts = cnt.astype(np.int64)
            return
        merged = np.union1d(self._ef_keys, u)
        cnts = np.zeros(merged.size, np.int64)
        cnts[np.searchsorted(merged, self._ef_keys)] = self._ef_cnts
        cnts[np.searchsorted(merged, u)] += cnt
        self._ef_keys, self._ef_cnts = merged, cnts

    def _freq_top(self, k: int):
        """The k highest-frequency (key, count) pairs this epoch."""
        c = self._ef_cnts
        if c.size > k:
            idx = np.argpartition(c, c.size - k)[-k:]
        else:
            idx = np.arange(c.size)
        kk = self._ef_keys
        return [(int(kk[i]), float(c[i])) for i in idx.tolist()
                if c[i] > 0]

    # ------------------------------------------------------------------
    def _alive_kns(self):
        return [n for n, k in self.c.kns.items() if k.alive]

    def _available(self, name: str) -> bool:
        for o in self.outages:
            if o.until > self.now and (o.node is None or o.node == name):
                return False
        if self.faults is not None and \
                self.faults.partitioned(name, "kn-dpm", self.now):
            return False    # cannot reach the DPM pool: ops don't serve
        return True

    def _blocked_fraction(self) -> float:
        """Fraction of this step's requests that hit an unavailable
        owner, weighted by how much of the step the outage overlaps."""
        names = self._alive_kns()
        if not names:
            return 1.0
        total = 0.0
        for o in self.outages:
            overlap = min(o.until, self.now + self.dt) - self.now
            if overlap <= 0:
                continue
            frac = min(overlap / self.dt, 1.0)
            if o.node is None:
                total += frac
            elif o.node in names:
                total += frac * self.c.ownership.ring.share(o.node,
                                                            samples=512)
        if self.faults is not None:
            seen = {o.node for o in self.outages if o.until > self.now}
            for nm in self.faults.partitioned_kns("kn-dpm", self.now):
                if nm in names and nm not in seen:
                    total += self.c.ownership.ring.share(nm, samples=512)
        return min(total, 1.0)

    # ------------------------------------------------------------------
    def step(self, offered_ops_per_s: float, events: list[str]):
        c, model = self.c, self.model
        n_sample = min(self.sample_ops, max(int(offered_ops_per_s * self.dt),
                                            1))
        ops = self.workload(self.now, self.rng, n_sample)
        c.reset_stats()
        # per-step DPM-processor merge budget: write-stall merges inside
        # the step and the async catch-up below share one allowance, so
        # neither the per-op loop nor a batched flush can merge more per
        # step than the processors could (merge_all -- the synchronous
        # reconfiguration merge -- is exempt)
        budget = int(model.merge_capacity() * self.dt)
        c.pool.merge_allowance = budget
        if self.batched:
            n_ops, per_kn_ops, writes = self._step_batched(ops)
        else:
            n_ops, per_kn_ops, writes = self._step_scalar(ops)
        c.advance_merge(budget)
        c.pool.merge_allowance = None

        stats = c.aggregate_stats()
        rts = max(stats["rts_per_op"], 1e-3)
        wf = writes / max(n_ops, 1)
        shares = self._load_shares(per_kn_ops)
        # hottest single-owner key: its effective share is divided by
        # its replication factor (paper Sec. 3.4 / selective replication)
        top_share = 0.0
        if self._epoch_total and c.variant.architecture \
                != "shared_everything":
            tot_f = self._epoch_total
            # top-8 without a full sort: the epoch-frequency vectors
            # hold every sampled key (paper-scale, batched plane)
            for k, f in self._freq_top(8):
                eff = (f / tot_f) / c.ownership.replication_factor(k)
                top_share = max(top_share, eff)
        cap = model.cluster_throughput(
            num_kns=max(len(self._alive_kns()), 1), rts_per_op=rts,
            value_bytes=c.value_bytes, write_fraction=wf,
            load_shares=shares,
            metadata_server_cap=(model.clover_ms_ops
                                 if c.variant.name == "clover" else None),
            ms_load_fraction=(1.0 - stats["hit_ratio"]) + wf,
            top_key_share=top_share)
        blocked = self._blocked_fraction()
        tput = min(offered_ops_per_s, cap) * (1.0 - blocked)
        util = offered_ops_per_s / max(cap, 1.0)
        queue = 1.0 / max(1.0 - min(util, 0.99), 0.01) if util > 0.7 else 1.0
        stale_penalty = 2.0 if events else 1.0   # mapping refresh hops
        # closed-loop queue estimate: a utilization-derived depth stands
        # in for the open-loop plane's real per-KN queues (run_open_loop
        # measures the real thing)
        avg_lat = model.request_latency(
            rts, queue_depth=queue * stale_penalty - 1.0)
        p99 = avg_lat * (4.0 + 8.0 * max(util - 0.8, 0.0) * 5.0)
        if blocked > 0:
            # requests to blocked owners wait for the outage (or the
            # partition window) to clear
            rems = [o.until - self.now for o in self.outages
                    if o.until > self.now]
            if self.faults is not None:
                rems.extend(p.end_s - self.now
                            for p in self.faults.partitions
                            if p.kind == "kn-dpm" and p.active(self.now))
            rem = max(rems, default=self.dt)
            avg_lat = avg_lat + blocked * min(rem, 0.5)
            p99 = max(p99, min(rem, 0.5) * 2.0)
        self.trace.append(TimePoint(self.now, tput, avg_lat, p99,
                                    len(self._alive_kns()),
                                    offered_ops_per_s, events))
        return util, avg_lat, p99, per_kn_ops, cap

    def _step_batched(self, ops):
        """Run the sampled ops through the vectorized data plane; the
        KN/cache statistics are identical to the per-op loop
        (property-tested). Ops owned by KNs inside an outage window
        are dropped exactly as the scalar loop drops them."""
        c = self.c
        if isinstance(ops, tuple):
            kinds, keys = ops
        else:
            n = len(ops)
            kinds = np.fromiter((0 if k == "read" else 1 for k, _ in ops),
                                np.uint8, n)
            keys = np.fromiter((key for _, key in ops), np.int64, n)
        blocked: set[str] = set()
        for o in self.outages:
            if o.until > self.now:
                if o.node is None:
                    blocked.update(c.kns)
                    break
                blocked.add(o.node)
        if self.faults is not None:
            # a KN partitioned from the DPM pool cannot serve: one-sided
            # reads/writes have nowhere to go (kn-mnode partitions only
            # hide heartbeats -- the data path keeps working)
            blocked.update(self.faults.partitioned_kns("kn-dpm", self.now)
                           & set(c.kns))
        res = c.execute_batch(kinds, keys, value=f"v@{self.now}",
                              blocked_kns=blocked, engine=self.engine)
        if res.executed:
            u, cnt = np.unique(res.executed_keys, return_counts=True)
            self._freq_add(u, cnt)
            self._epoch_total += float(res.executed)
        return kinds.shape[0], res.per_kn, res.writes

    def _step_scalar(self, ops):
        """The original per-op sampling loop (equivalence baseline)."""
        c = self.c
        if isinstance(ops, tuple):
            kinds, keys = ops
            ops = [("read" if kd == 0 else "write", int(k))
                   for kd, k in zip(kinds, keys)]
        per_kn_ops: dict[str, int] = {}
        writes = 0
        step_freq: dict[int, int] = {}
        for kind, key in ops:
            try:
                kn = c.route(key)
            except KeyError:
                continue
            if not self._available(kn):
                continue
            per_kn_ops[kn] = per_kn_ops.get(kn, 0) + 1
            if kind == "read":
                c.read(key, kn)
            else:
                writes += 1
                c.write(key, f"v@{self.now}", kn)
            step_freq[key] = step_freq.get(key, 0) + 1
            self._epoch_total += 1.0
        if step_freq:
            u = np.fromiter(sorted(step_freq), np.int64, len(step_freq))
            cnt = np.fromiter((step_freq[k] for k in u.tolist()),
                              np.int64, u.size)
            self._freq_add(u, cnt)
        return len(ops), per_kn_ops, writes

    def _load_shares(self, per_kn_ops: dict[str, int]):
        tot = sum(per_kn_ops.values())
        names = self._alive_kns()
        if not tot or not names:
            return None
        return [per_kn_ops.get(n, 0) / tot for n in names]

    # ------------------------------------------------------------------
    def run(self, duration: float, offered_fn, inject=None):
        """``offered_fn(t)`` -> ops/s; ``inject(t, sim)`` optional event
        hook (e.g. failures). Runs the M-node policy every epoch."""
        cfg = self.c.mnode.cfg
        while self.now < duration:
            events: list[str] = []
            if inject is not None:
                ev = inject(self.now, self)
                if ev:
                    events.append(ev)
            util, avg_lat, p99, per_kn, cap = self.step(
                offered_fn(self.now), events)
            self.now += self.dt
            if self.now >= self._next_epoch:
                self._run_epoch(avg_lat, p99, per_kn, cap)
                self._next_epoch = self.now + cfg.epoch_s

    def _run_epoch(self, avg_lat, p99, per_kn, cap):
        c = self.c
        names = self._alive_kns()
        if not names:
            return
        kn_cap = cap / max(len(names), 1) if cap else 1.0
        occupancy = {}
        tot = sum(per_kn.values()) or 1
        offered = self.trace[-1].offered if self.trace else 0.0
        for n in names:
            share = per_kn.get(n, 0) / tot
            kn_rate = share * offered
            occupancy[n] = min(kn_rate / max(self.model.kn_cpu_ops, 1.0),
                               1.0)
        epoch_s = c.mnode.cfg.epoch_s
        stats = EpochStats(
            now=self.now, avg_latency=avg_lat, p99_latency=p99,
            occupancy=occupancy,
            key_freq={k: f / epoch_s for k, f in self._freq_top(64)},
            replication={k: c.ownership.replication_factor(k)
                         for k in c.ownership.replicated},
        )
        for action in c.mnode.decide(stats):
            self._apply(action)
        self._ef_keys = np.empty(0, np.int64)
        self._ef_cnts = np.empty(0, np.int64)
        self._epoch_total = 0.0

    def _apply(self, action):
        c = self.c
        if action.kind == "add_kn":
            name, _ = c.add_kn()
            self._post_reconfig(name)
        elif action.kind == "remove_kn" and action.node in c.kns:
            alive = self._alive_kns()
            if len(alive) <= 1 and action.node in alive:
                # removing the last alive KN would leave an empty ring;
                # refuse with a reason rather than corrupt routing
                self.log_event("refused", action="remove_kn",
                               node=action.node, reason="last alive KN")
                return
            c.remove_kn(action.node)
            self._post_reconfig(None)
        elif action.kind == "replicate":
            c.replicate_key(action.key, action.factor)
        elif action.kind == "dereplicate":
            c.dereplicate_key(action.key)

    def _post_reconfig(self, node: str | None):
        """Translate the protocol's synchronous work into outage windows."""
        rec = self.c.reconfig_log[-1] if self.c.reconfig_log else None
        if rec is None:
            return
        merge_s = rec["merged_entries"] / max(self.model.merge_capacity(), 1)
        if self.c.variant.architecture == "shared_nothing":
            # physical data reorganization blocks the cluster
            dataset_bytes = self.dataset_bytes or \
                len(self.c.pool.heap_val) * self.c.value_bytes
            move_s = rec["moved_fraction"] * dataset_bytes \
                / self.model.reorg_bw
            self.outages.append(Outage(None, self.now + merge_s + move_s,
                                       "data reorganization"))
        else:
            for p in rec["participants"]:
                self.outages.append(Outage(
                    p, self.now + merge_s + self.model.handoff_s,
                    "ownership handoff"))

    # ------------------------------------------------------------------
    def run_open_loop(self, duration: float, arrival, config=None,
                      on_crash=None):
        """Drive the cluster *open-loop* for ``duration`` seconds:
        requests arrive on ``arrival``'s schedule (an ArrivalProcess /
        PhasedArrival), queue at their owner KN's bounded FIFO, and
        live through the full backpressure / deadline / retry / hedge
        machinery (core.requestplane).  Ops sample from this
        simulation's workload and run against the real data structures
        through execute_batch; request-plane events land on this
        simulation's event_log timeline.  Returns the
        ``RequestPlaneResult`` (per-op records, latency percentiles,
        shed/retry counters)."""
        from .requestplane import RequestPlane, RequestPlaneConfig
        plane = RequestPlane(
            self.c, arrival, self.workload,
            cfg=config or RequestPlaneConfig(), model=self.model,
            seed=int(self.rng.integers(1 << 31)), t0=self.now,
            event_sink=self.event_log, on_crash=on_crash)
        res = plane.run(duration)
        self.now += duration
        self.log_event("open_loop_done",
                       offered_rate=res.offered_rate,
                       goodput=res.goodput(),
                       completed=res.counters["completed"],
                       shed=res.counters["shed"],
                       retries=res.counters["retries"])
        return res

    # ------------------------------------------------------------------
    def inject_failure(self, name: str, extra_detect_s: float = 0.0) -> float:
        """Fail a KN; returns the recovery window in seconds.  Timing
        constants come from the NetModel (detect_s / handoff_s /
        clover_refresh_s) so scenarios can sweep them; an attached
        FaultPlane adds its heartbeat delay to detection.  Failing the
        last alive KN is refused (window 0.0, reason logged): a cluster
        with an empty ring cannot recover ownership anywhere."""
        c = self.c
        alive = self._alive_kns()
        if name not in c.kns or (len(alive) <= 1 and name in alive):
            self.log_event("refused", action="inject_failure", node=name,
                           reason=("unknown KN" if name not in c.kns
                                   else "last alive KN"))
            return 0.0
        detect_s = self.model.detect_s + extra_detect_s   # heartbeat miss
        if self.faults is not None:
            detect_s += self.faults.heartbeat_delay()
        ev = c.fail_kn(name)
        rec = c.reconfig_log[-1]
        merge_s = rec["merged_entries"] / max(self.model.merge_capacity(), 1)
        if c.variant.architecture == "shared_nothing":
            dataset_bytes = self.dataset_bytes or \
                len(c.pool.heap_val) * c.value_bytes
            window = detect_s + merge_s + rec["moved_fraction"] \
                * dataset_bytes / self.model.reorg_bw
            self.outages.append(Outage(None, self.now + window,
                                       "failure reorganization"))
        elif c.variant.name == "clover":
            window = detect_s + self.model.clover_refresh_s   # refresh only
            self.outages.append(Outage(None, self.now + window,
                                       "membership refresh"))
        else:
            window = detect_s + merge_s + self.model.handoff_s
            for p in rec["participants"]:
                if p in c.kns:
                    self.outages.append(Outage(p, self.now + window,
                                               "failover"))
        self.c.mnode.note_failure(self.now)
        # detect_s = effective detection latency (heartbeat miss + any
        # FaultPlane heartbeat delay): scenarios gate on a detection SLO
        self.log_event("kn_failed", node=name, window_s=window,
                       detect_s=round(detect_s, 6))
        return window
