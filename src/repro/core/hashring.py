"""Consistent-hash rings for Ownership Partitioning (paper Sec. 3.4).

Two rings, as in the paper:
  * the *global* ring maps keys -> KN ids   (kept by RNs and KNs)
  * a *local* ring per KN maps keys -> thread ids

Rings are pure-python and deterministic (stdlib hash is salted per
process, so we use a splitmix-style mixer).  The ring also exposes the
partition boundaries so ownership handoffs can be expressed as ranges.
"""

from __future__ import annotations

import bisect
from typing import Hashable, Iterable

_MASK64 = (1 << 64) - 1


def mix64(x: int) -> int:
    """splitmix64 finalizer: deterministic 64-bit hash of an int."""
    x = (x + 0x9E3779B97F4A7C15) & _MASK64
    x = ((x ^ (x >> 30)) * 0xBF58476D1CE4E5B9) & _MASK64
    x = ((x ^ (x >> 27)) * 0x94D049BB133111EB) & _MASK64
    return (x ^ (x >> 31)) & _MASK64


def stable_hash(key: Hashable) -> int:
    if isinstance(key, int):
        return mix64(key)
    if isinstance(key, bytes):
        h = 0xCBF29CE484222325
        for b in key:
            h = ((h ^ b) * 0x100000001B3) & _MASK64
        return mix64(h)
    if isinstance(key, str):
        return stable_hash(key.encode())
    return stable_hash(repr(key).encode())


class HashRing:
    """Consistent-hash ring with virtual nodes.

    Adding/removing a member only remaps the key ranges adjacent to that
    member's virtual nodes -- the property that makes OP reconfiguration
    lightweight (only ownership metadata moves, never data).
    """

    def __init__(self, members: Iterable[str] = (), vnodes: int = 64):
        self.vnodes = vnodes
        self._points: list[int] = []     # sorted vnode positions
        self._owners: list[str] = []     # owner of each vnode position
        self._members: set[str] = set()
        for m in members:
            self.add(m)

    # -- membership ---------------------------------------------------------
    def add(self, member: str) -> None:
        if member in self._members:
            return
        self._members.add(member)
        for v in range(self.vnodes):
            pos = stable_hash(f"{member}#{v}")
            i = bisect.bisect_left(self._points, pos)
            self._points.insert(i, pos)
            self._owners.insert(i, member)

    def remove(self, member: str) -> None:
        if member not in self._members:
            return
        self._members.discard(member)
        keep = [(p, o) for p, o in zip(self._points, self._owners)
                if o != member]
        self._points = [p for p, _ in keep]
        self._owners = [o for _, o in keep]

    @property
    def members(self) -> list[str]:
        return sorted(self._members)

    def __len__(self) -> int:
        return len(self._members)

    def __contains__(self, member: str) -> bool:
        return member in self._members

    # -- lookup ---------------------------------------------------------------
    def owner(self, key: Hashable) -> str:
        if not self._points:
            raise RuntimeError("empty hash ring")
        pos = stable_hash(key)
        i = bisect.bisect_right(self._points, pos)
        if i == len(self._points):
            i = 0
        return self._owners[i]

    def owners(self, key: Hashable, n: int) -> list[str]:
        """The n distinct successors of the key's position: the primary owner
        followed by candidate secondary owners (for selective replication)."""
        if not self._points:
            raise RuntimeError("empty hash ring")
        pos = stable_hash(key)
        i = bisect.bisect_right(self._points, pos)
        out: list[str] = []
        seen: set[str] = set()
        for step in range(len(self._points)):
            o = self._owners[(i + step) % len(self._points)]
            if o not in seen:
                seen.add(o)
                out.append(o)
                if len(out) == n:
                    break
        return out

    # -- introspection ---------------------------------------------------------
    def share(self, member: str, samples: int = 4096) -> float:
        """Approximate fraction of the keyspace owned by ``member``."""
        hits = sum(1 for k in range(samples) if self.owner(k) == member)
        return hits / samples

    def diff(self, other: "HashRing", samples: int = 4096) -> float:
        """Fraction of sampled keys whose owner differs between two rings
        (the reconfiguration 'blast radius')."""
        if not self._points or not other._points:
            return 1.0
        moved = sum(1 for k in range(samples)
                    if self.owner(k) != other.owner(k))
        return moved / samples

    def snapshot(self) -> "HashRing":
        r = HashRing(vnodes=self.vnodes)
        r._points = list(self._points)
        r._owners = list(self._owners)
        r._members = set(self._members)
        return r
