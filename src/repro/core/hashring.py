"""Consistent-hash rings for Ownership Partitioning (paper Sec. 3.4).

Two rings, as in the paper:
  * the *global* ring maps keys -> KN ids   (kept by RNs and KNs)
  * a *local* ring per KN maps keys -> thread ids

Rings are pure-python and deterministic (stdlib hash is salted per
process, so we use a splitmix-style mixer).  The ring also exposes the
partition boundaries so ownership handoffs can be expressed as ranges.
"""

from __future__ import annotations

import bisect
from typing import Hashable, Iterable

import numpy as np

_MASK64 = (1 << 64) - 1


def mix64(x: int) -> int:
    """splitmix64 finalizer: deterministic 64-bit hash of an int."""
    x = (x + 0x9E3779B97F4A7C15) & _MASK64
    x = ((x ^ (x >> 30)) * 0xBF58476D1CE4E5B9) & _MASK64
    x = ((x ^ (x >> 27)) * 0x94D049BB133111EB) & _MASK64
    return (x ^ (x >> 31)) & _MASK64


def mix64_batch(x: np.ndarray) -> np.ndarray:
    """Vectorized splitmix64: bit-identical to ``mix64`` per element."""
    x = x.astype(np.uint64, copy=True)
    x += np.uint64(0x9E3779B97F4A7C15)
    x = (x ^ (x >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
    x = (x ^ (x >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
    return x ^ (x >> np.uint64(31))


def stable_hash(key: Hashable) -> int:
    if isinstance(key, int):
        return mix64(key)
    if isinstance(key, bytes):
        h = 0xCBF29CE484222325
        for b in key:
            h = ((h ^ b) * 0x100000001B3) & _MASK64
        return mix64(h)
    if isinstance(key, str):
        return stable_hash(key.encode())
    return stable_hash(repr(key).encode())


class HashRing:
    """Consistent-hash ring with virtual nodes.

    Adding/removing a member only remaps the key ranges adjacent to that
    member's virtual nodes -- the property that makes OP reconfiguration
    lightweight (only ownership metadata moves, never data).
    """

    def __init__(self, members: Iterable[str] = (), vnodes: int = 64):
        self.vnodes = vnodes
        self._points: list[int] = []     # sorted vnode positions
        self._owners: list[str] = []     # owner of each vnode position
        self._members: set[str] = set()
        self.generation = 0              # bumped on every membership change
        self._np_cache = None            # (points, owner_ids, names)
        self._share_cache: dict[int, np.ndarray] = {}  # samples -> ids
        for m in members:
            self.add(m)

    # -- membership ---------------------------------------------------------
    def _invalidate(self) -> None:
        self.generation += 1
        self._np_cache = None
        self._share_cache.clear()

    def add(self, member: str) -> None:
        if member in self._members:
            return
        self._members.add(member)
        for v in range(self.vnodes):
            pos = stable_hash(f"{member}#{v}")
            i = bisect.bisect_left(self._points, pos)
            self._points.insert(i, pos)
            self._owners.insert(i, member)
        self._invalidate()

    def remove(self, member: str) -> None:
        if member not in self._members:
            return
        self._members.discard(member)
        keep = [(p, o) for p, o in zip(self._points, self._owners)
                if o != member]
        self._points = [p for p, _ in keep]
        self._owners = [o for _, o in keep]
        self._invalidate()

    @property
    def members(self) -> list[str]:
        return sorted(self._members)

    def __len__(self) -> int:
        return len(self._members)

    def __contains__(self, member: str) -> bool:
        return member in self._members

    # -- lookup ---------------------------------------------------------------
    def owner(self, key: Hashable) -> str:
        if not self._points:
            raise RuntimeError("empty hash ring")
        pos = stable_hash(key)
        i = bisect.bisect_right(self._points, pos)
        if i == len(self._points):
            i = 0
        return self._owners[i]

    def owners(self, key: Hashable, n: int) -> list[str]:
        """The n distinct successors of the key's position: the primary owner
        followed by candidate secondary owners (for selective replication)."""
        if not self._points:
            raise RuntimeError("empty hash ring")
        pos = stable_hash(key)
        i = bisect.bisect_right(self._points, pos)
        out: list[str] = []
        seen: set[str] = set()
        for step in range(len(self._points)):
            o = self._owners[(i + step) % len(self._points)]
            if o not in seen:
                seen.add(o)
                out.append(o)
                if len(out) == n:
                    break
        return out

    # -- vectorized lookup (the batched data plane's routing path) ----------
    def _np_view(self):
        """(sorted vnode positions, owner id per position, names) --
        cached numpy mirror of the ring, rebuilt on membership change."""
        if self._np_cache is None:
            names = sorted(self._members)
            idx = {n: i for i, n in enumerate(names)}
            points = np.asarray(self._points, dtype=np.uint64)
            owner_ids = np.asarray([idx[o] for o in self._owners],
                                   dtype=np.int64)
            self._np_cache = (points, owner_ids, names)
        return self._np_cache

    def owner_ids(self, keys: np.ndarray):
        """Vectorized ``owner`` for int keys: returns (ids, names) where
        ``names[ids[i]]`` == ``self.owner(int(keys[i]))`` exactly."""
        points, owner_ids, names = self._np_view()
        if not len(points):
            raise RuntimeError("empty hash ring")
        pos = mix64_batch(np.asarray(keys))
        i = np.searchsorted(points, pos, side="right")
        i[i == len(points)] = 0
        return owner_ids[i], names

    def _sample_ids(self, samples: int) -> np.ndarray:
        ids = self._share_cache.get(samples)
        if ids is None:
            ids, _ = self.owner_ids(np.arange(samples, dtype=np.uint64))
            self._share_cache[samples] = ids
        return ids

    # -- introspection ---------------------------------------------------------
    def share(self, member: str, samples: int = 4096) -> float:
        """Approximate fraction of the keyspace owned by ``member``."""
        if not self._points or member not in self._members:
            return 0.0
        _, _, names = self._np_view()
        mid = names.index(member)
        ids = self._sample_ids(samples)
        return int((ids == mid).sum()) / samples

    def diff(self, other: "HashRing", samples: int = 4096) -> float:
        """Fraction of sampled keys whose owner differs between two rings
        (the reconfiguration 'blast radius')."""
        if not self._points or not other._points:
            return 1.0
        a_ids = self._sample_ids(samples)
        b_ids = other._sample_ids(samples)
        _, _, a_names = self._np_view()
        _, _, b_names = other._np_view()
        a = np.asarray(a_names, dtype=object)[a_ids]
        b = np.asarray(b_names, dtype=object)[b_ids]
        return int((a != b).sum()) / samples

    def snapshot(self) -> "HashRing":
        r = HashRing(vnodes=self.vnodes)
        r._points = list(self._points)
        r._owners = list(self._owners)
        r._members = set(self._members)
        return r
